"""Automated diagnostics: a detector registry layered on the op registry.

Pipit's pitch is that a programmatic trace API lets users "quickly and
easily identify performance issues" — this module is the part that actually
*names* the issue.  A **detector** is a registered analysis op
(:func:`register_detector` wraps :func:`~repro.core.registry.register_op`)
that returns a ranked ``Findings`` frame: one row per suspected problem
with a location, a severity score, the time window it covers, and a
human-readable explanation — the same report-not-raw-numbers contract
``regression_report`` established for run comparisons.  Because detectors
are ordinary registry ops they work everywhere ops do: eagerly
(``trace.stragglers()``), through a lazy plan
(``trace.query().slice_time(...).diagnose()``), out of core over streaming
handles, fanned out across the parallel executor (every built-in detector
registers a combinable, cross-worker-mergeable aggregator), against packs,
via the plan cache, and remotely through the trace-query service's
``/diagnose`` endpoint.

Shipped detectors (grounded in "Automated Programmatic Performance
Analysis of Parallel Programs", arxiv 2401.13150, and the POP-style
time-resolved metrics of arxiv 2512.01764):

``late_sender``
    message pairs where the sender posted after the receiver was already
    waiting (and, symmetrically, receivers that pick messages up
    anomalously late), attributed to the offending rank.
``stragglers``
    ranks whose non-communication work exceeds the mean by a threshold —
    the "one slow rank drags the collective" pathology.
``serialization``
    processes where one thread holds nearly all the busy time while the
    other threads sit idle (work that was meant to overlap, serialized).
``imbalance_root_cause``
    *which functions* drive load imbalance: per-function cross-rank
    max-minus-mean cost, attributed to the dominant rank.
``pop_efficiency``
    time-resolved POP efficiency metrics (parallel / load-balance /
    communication efficiency per time window, see
    :func:`efficiency_metrics`), flagging windows whose parallel
    efficiency drops well below the trace's own median.

Every severity is computed from exactly-summable integer-nanosecond
accumulations, so the streaming and parallel paths reproduce the eager
result bit for bit (the closed-loop suites in ``tests/test_detectors.py``
assert digest equality on every path).
"""

from __future__ import annotations

import inspect
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from .constants import (DEFAULT_COMM_PREFIXES, DEFAULT_IDLE_NAMES, ENTER,
                        ET, EXC, INC, LEAVE, MPI_RECV, MPI_SEND, NAME,
                        PARTNER, PROC, TAG, THREAD, TS)
from . import accel
from .frame import EventFrame
from .registry import (get_backend, register_backend, register_op,
                       register_streaming)
from .streaming import StreamAgg, StreamingUnsupported, grow_to

__all__ = ["DetectorSpec", "register_detector", "get_detector",
           "list_detectors", "Findings", "FINDINGS_COLUMNS", "is_comm_name",
           "late_sender", "stragglers", "serialization",
           "imbalance_root_cause", "pop_efficiency", "efficiency_metrics",
           "diagnose"]


# ---------------------------------------------------------------------------
# Findings frame schema
# ---------------------------------------------------------------------------

DETECTOR = "detector"
LOCATION = "location"
F_PROCESS = "process"
F_FUNCTION = "function"
SEVERITY = "severity"
T_START = "t_start"
T_END = "t_end"
EXPLANATION = "explanation"

#: column order of every Findings frame
FINDINGS_COLUMNS = (DETECTOR, LOCATION, F_PROCESS, F_FUNCTION, SEVERITY,
                    T_START, T_END, EXPLANATION)


def Findings(rows: Sequence[dict]) -> EventFrame:
    """Build a ranked Findings frame from per-finding dicts.

    Rows are sorted by severity descending (ties broken by detector name,
    then location — a total, deterministic order, so eager / streaming /
    parallel executions produce byte-identical frames).  ``process`` is -1
    and ``function`` is ``""`` where not applicable.
    """
    rows = sorted(rows, key=lambda r: (-r[SEVERITY], r[DETECTOR],
                                       r[LOCATION], r[F_PROCESS]))
    return EventFrame({
        DETECTOR: np.asarray([r[DETECTOR] for r in rows], dtype=object),
        LOCATION: np.asarray([r[LOCATION] for r in rows], dtype=object),
        F_PROCESS: np.asarray([int(r.get(F_PROCESS, -1)) for r in rows],
                              np.int64),
        F_FUNCTION: np.asarray([r.get(F_FUNCTION, "") for r in rows],
                               dtype=object),
        SEVERITY: np.asarray([float(r[SEVERITY]) for r in rows], np.float64),
        T_START: np.asarray([float(r.get(T_START, 0.0)) for r in rows],
                            np.float64),
        T_END: np.asarray([float(r.get(T_END, 0.0)) for r in rows],
                          np.float64),
        EXPLANATION: np.asarray([r[EXPLANATION] for r in rows],
                                dtype=object),
    })


def _ms(ns: float) -> str:
    return f"{ns / 1e6:.3f} ms"


# ---------------------------------------------------------------------------
# detector registry (layered on the op registry)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DetectorSpec:
    """Metadata the detector layer keeps on top of the op registry entry:
    the pathology category, the default severity threshold (findings below
    it are suppressed), and a one-line description for docs/catalogs."""

    name: str
    fn: Callable
    category: str
    threshold: float
    description: str


_DETECTOR_REGISTRY: Dict[str, DetectorSpec] = {}


def register_detector(name: str, *, category: str, threshold: float,
                      needs_structure: bool = False,
                      needs_messages: bool = False) -> Callable:
    """Register ``fn(trace, ...) -> Findings`` as a detector.

    The function is registered as an ordinary ``scope="trace"`` op (so it
    is a lazy-query terminal, service-callable, cacheable, and — once a
    streaming aggregator is attached via ``register_streaming`` — runs out
    of core and in parallel), *and* recorded in the detector registry so
    ``diagnose`` and the docs generator can enumerate it.
    """
    def deco(fn: Callable) -> Callable:
        wrapped = register_op(name, needs_structure=needs_structure,
                              needs_messages=needs_messages)(fn)
        doc = inspect.getdoc(fn)
        desc = doc.splitlines()[0].rstrip() if doc else ""
        _DETECTOR_REGISTRY[name] = DetectorSpec(
            name=name, fn=fn, category=category, threshold=float(threshold),
            description=desc)
        return wrapped
    return deco


def get_detector(name: str) -> Optional[DetectorSpec]:
    """The DetectorSpec for ``name``, or None if the op is not a detector."""
    return _DETECTOR_REGISTRY.get(name)


def list_detectors() -> List[str]:
    """Registered detector names, sorted."""
    return sorted(_DETECTOR_REGISTRY)


# ---------------------------------------------------------------------------
# shared classification / accumulation helpers
# ---------------------------------------------------------------------------

_COMM_SUBSTRINGS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute", "nccl", "send",
                    "recv")


def is_comm_name(name: str) -> bool:
    """Whether a function name is communication/wait rather than useful
    computation — the classification every detector shares (one pure
    function of the *string*, so eager and streaming paths agree by
    construction)."""
    s = str(name)
    low = s.lower()
    return (s.startswith(DEFAULT_COMM_PREFIXES)
            or any(t in low for t in _COMM_SUBSTRINGS)
            or s in DEFAULT_IDLE_NAMES)


def _comm_cat_mask(categories) -> np.ndarray:
    return np.asarray([is_comm_name(c) for c in categories], dtype=bool)


class _NameClassCache:
    """Incrementally classify a growing GlobalNames table as comm/useful —
    streaming aggregators call this per chunk; only newly interned names
    pay the string checks."""

    def __init__(self):
        self._mask = np.zeros(0, dtype=bool)

    def mask(self, names) -> np.ndarray:
        have = len(self._mask)
        want = len(names)
        if want > have:
            fresh = np.asarray([is_comm_name(n)
                                for n in names.names[have:want]], dtype=bool)
            self._mask = np.concatenate([self._mask, fresh])
        return self._mask[:want]


def _fifo_pairs(s_ts, s_src, s_dst, s_tag, r_ts, r_src, r_dst, r_tag):
    """FIFO-match send/recv instants per (src, dst, tag) channel, exactly
    like :func:`repro.core.structure.match_messages`: both sides sorted by
    timestamp within a channel, k-th send paired with k-th recv.

    Returns ``(send_ts, recv_ts, src, dst)`` int64/float arrays of the
    matched pairs (channel-major order — every consumer aggregates, so
    order inside is irrelevant; the *multiset* of pairs is what matches
    the in-memory path).
    """
    if len(s_ts) == 0 or len(r_ts) == 0:
        z = np.empty(0, np.int64)
        return z, z, z.copy(), z.copy()
    hi = int(max(s_src.max(), s_dst.max(), r_src.max(), r_dst.max())) + 1
    ht = int(max(s_tag.max() if len(s_tag) else 0,
                 r_tag.max() if len(r_tag) else 0)) + 2
    s_key = (s_src * hi + s_dst) * ht + s_tag
    r_key = (r_src * hi + r_dst) * ht + r_tag
    so = np.lexsort((s_ts, s_key))
    ro = np.lexsort((r_ts, r_key))
    s_key, s_ts, s_src, s_dst = s_key[so], s_ts[so], s_src[so], s_dst[so]
    r_key, r_ts = r_key[ro], r_ts[ro]
    out_s, out_r, out_src, out_dst = [], [], [], []
    keys = np.unique(np.concatenate([s_key, r_key]))
    for k in keys:
        si = np.nonzero(s_key == k)[0]
        ri = np.nonzero(r_key == k)[0]
        m = min(len(si), len(ri))
        if m == 0:
            continue
        out_s.append(s_ts[si[:m]])
        out_r.append(r_ts[ri[:m]])
        out_src.append(s_src[si[:m]])
        out_dst.append(s_dst[si[:m]])
    if not out_s:
        z = np.empty(0, np.int64)
        return z, z, z.copy(), z.copy()
    return (np.concatenate(out_s), np.concatenate(out_r),
            np.concatenate(out_src), np.concatenate(out_dst))


def _late_findings(send_ts, recv_ts, src, dst, span, nprocs, threshold,
                   late_recv_margin):
    """Shared eager/streaming finalization for :func:`late_sender` —
    everything integer-ns until the final severity division."""
    rows: List[dict] = []
    if len(send_ts) == 0 or span <= 0:
        return Findings(rows)
    # -- late sender: message posted after the receiver reached its recv
    wait = np.maximum(send_ts - recv_ts, 0)
    tot = np.zeros(nprocs, np.int64)
    cnt = np.zeros(nprocs, np.int64)
    w0 = np.full(nprocs, np.iinfo(np.int64).max, np.int64)
    w1 = np.full(nprocs, np.iinfo(np.int64).min, np.int64)
    late = wait > 0
    np.add.at(tot, src[late], wait[late])
    np.add.at(cnt, src[late], 1)
    np.minimum.at(w0, src[late], send_ts[late])
    np.maximum.at(w1, src[late], send_ts[late])
    for p in range(nprocs):
        sev = float(tot[p]) / float(span)
        if sev >= threshold:
            rows.append({
                DETECTOR: "late_sender",
                LOCATION: f"rank {p} (sender)",
                F_PROCESS: int(p), F_FUNCTION: MPI_SEND,
                SEVERITY: sev,
                T_START: float(w0[p]), T_END: float(w1[p]),
                EXPLANATION: (
                    f"{int(cnt[p])} messages from rank {p} were posted "
                    f"after their receiver was already waiting "
                    f"({_ms(float(tot[p]))} total receiver wait, "
                    f"{sev * 100:.1f}% of the trace span)"),
            })
    # -- late receiver: pick-up lag far beyond the trace's typical lag
    lag = np.maximum(recv_ts - send_ts, 0)
    med = int(np.floor(np.median(lag)))
    cut = int(late_recv_margin * med)
    if cut > 0:
        excess = np.maximum(lag - cut, 0)
        rtot = np.zeros(nprocs, np.int64)
        rcnt = np.zeros(nprocs, np.int64)
        r0 = np.full(nprocs, np.iinfo(np.int64).max, np.int64)
        r1 = np.full(nprocs, np.iinfo(np.int64).min, np.int64)
        slow = excess > 0
        np.add.at(rtot, dst[slow], excess[slow])
        np.add.at(rcnt, dst[slow], 1)
        np.minimum.at(r0, dst[slow], recv_ts[slow])
        np.maximum.at(r1, dst[slow], recv_ts[slow])
        for p in range(nprocs):
            sev = float(rtot[p]) / float(span)
            if sev >= threshold:
                rows.append({
                    DETECTOR: "late_sender",
                    LOCATION: f"rank {p} (receiver)",
                    F_PROCESS: int(p), F_FUNCTION: MPI_RECV,
                    SEVERITY: sev,
                    T_START: float(r0[p]), T_END: float(r1[p]),
                    EXPLANATION: (
                        f"rank {p} picked up {int(rcnt[p])} messages "
                        f"{late_recv_margin:g}x later than the typical "
                        f"send-to-recv lag ({_ms(float(med))}), "
                        f"{_ms(float(rtot[p]))} excess in total"),
                })
    return Findings(rows)


# ---------------------------------------------------------------------------
# detector 1: late sender / late receiver
# ---------------------------------------------------------------------------

@register_detector("late_sender", category="communication", threshold=0.01,
                   needs_messages=True)
def late_sender(trace, threshold: float = 0.01,
                late_recv_margin: float = 4.0) -> EventFrame:
    """Message pairs whose sender posted late (receiver sat waiting) or
    whose receiver picked up anomalously late.

    For every FIFO-matched MpiSend/MpiRecv pair: if the send instant comes
    *after* the matched recv instant, the receiver reached its receive
    point first and idled for ``send_ts - recv_ts`` — that wait is charged
    to the sending rank.  Symmetrically, a pair whose pick-up lag
    (``recv_ts - send_ts``) exceeds ``late_recv_margin`` times the trace's
    median lag charges the excess to the receiving rank.

    Args:
        threshold: minimum severity (total charged wait as a fraction of
            the trace span) for a rank to be reported.
        late_recv_margin: multiple of the median send-to-recv lag beyond
            which a receiver counts as late.

    Returns:
        Findings frame — ``process`` is the offending rank, ``function``
        is ``MpiSend`` (late sender) or ``MpiRecv`` (late receiver), the
        window spans the offending messages.
    """
    ev = trace.events
    n = len(ev)
    rows: List[dict] = []
    mm = getattr(trace, "_msg_match", None)
    if n == 0 or mm is None:
        return Findings(rows)
    ts = np.asarray(ev[TS], np.int64)
    name = ev.cat(NAME)
    sends = np.nonzero(name.mask_eq(MPI_SEND) & (mm >= 0))[0]
    if len(sends) == 0:
        return Findings(rows)
    proc = np.asarray(ev[PROC], np.int64)
    send_ts = ts[sends]
    recv_ts = ts[mm[sends]]
    src = proc[sends]
    dst = proc[mm[sends]]
    span = int(ts.max()) - int(ts.min())
    return _late_findings(send_ts, recv_ts, src, dst, span,
                          trace.num_processes, threshold, late_recv_margin)


@register_streaming("late_sender")
class _LateSenderAgg(StreamAgg):
    """Collects send/recv instants per chunk (compact column arrays) and
    FIFO-matches them at finalize — memory is O(#messages), the pairing
    multiset matches ``match_messages`` exactly, and all severities are
    integer-ns sums, so results are byte-identical to eager on every
    path."""

    needs_stats = True
    supports_parallel = True

    def __init__(self, threshold: float = 0.01,
                 late_recv_margin: float = 4.0):
        self.threshold = float(threshold)
        self.late_recv_margin = float(late_recv_margin)
        self._sends: List[np.ndarray] = []
        self._recvs: List[np.ndarray] = []

    def _grab(self, ev, mask, ts, proc, partner, tag, into) -> None:
        rows = np.nonzero(mask)[0]
        if len(rows):
            into.append(np.stack([ts[rows], proc[rows], partner[rows],
                                  tag[rows]]))

    def update(self, chunk) -> None:
        ev = chunk.events
        if PARTNER not in ev or len(ev) == 0:
            return
        name = ev.cat(NAME)
        is_send = name.mask_eq(MPI_SEND)
        is_recv = name.mask_eq(MPI_RECV)
        if not (is_send.any() or is_recv.any()):
            return
        ts = np.asarray(ev[TS], np.int64)
        proc = np.asarray(ev[PROC], np.int64)
        partner = np.asarray(ev[PARTNER], np.int64)
        tag = (np.asarray(ev[TAG], np.int64) if TAG in ev
               else np.zeros(len(ev), np.int64))
        self._grab(ev, is_send, ts, proc, partner, tag, self._sends)
        self._grab(ev, is_recv, ts, proc, partner, tag, self._recvs)

    def merge_from(self, other, code_map) -> None:
        self._sends.extend(other._sends)
        self._recvs.extend(other._recvs)

    def result(self, ctx) -> EventFrame:
        if not self._sends or not self._recvs:
            return Findings([])
        s = np.concatenate(self._sends, axis=1)
        r = np.concatenate(self._recvs, axis=1)
        send_ts, recv_ts, src, dst = _fifo_pairs(
            s[0], s[1], s[2], s[3], r[0], r[2], r[1], r[3])
        span = int(ctx.stats.ts_max) - int(ctx.stats.ts_min)
        return _late_findings(send_ts, recv_ts, src, dst, span,
                              ctx.num_processes, self.threshold,
                              self.late_recv_margin)


# ---------------------------------------------------------------------------
# detector 2: straggler ranks
# ---------------------------------------------------------------------------

def _straggler_findings(work, t0, t1, nprocs, threshold):
    rows: List[dict] = []
    work = work[:nprocs]
    mean = float(work.sum()) / max(nprocs, 1)
    if mean <= 0:
        return Findings(rows)
    for p in range(nprocs):
        sev = (float(work[p]) - mean) / mean
        if sev >= threshold:
            rows.append({
                DETECTOR: "stragglers",
                LOCATION: f"rank {p}",
                F_PROCESS: int(p), F_FUNCTION: "",
                SEVERITY: sev,
                T_START: float(t0[p]), T_END: float(t1[p]),
                EXPLANATION: (
                    f"rank {p} spent {_ms(float(work[p]))} in computation "
                    f"vs a {_ms(mean)} mean across {nprocs} ranks "
                    f"({sev * 100:.1f}% above the mean)"),
            })
    return Findings(rows)


@register_detector("stragglers", category="imbalance", threshold=0.2,
                   needs_structure=True)
def stragglers(trace, threshold: float = 0.2,
               backend: str = "numpy") -> EventFrame:
    """Ranks whose useful (non-communication) work is far above the mean.

    Sums exclusive time of non-communication calls per rank; a rank whose
    total exceeds the cross-rank mean by ``threshold`` (relative excess,
    0.2 = 20% above the mean) is reported — the classic straggler every
    collective then waits for.

    Args:
        threshold: relative excess over the cross-rank mean that flags a
            rank.
        backend: ``"numpy"`` (default, exact) or ``"pallas"`` (per-rank
            busy sums through the seg_sum one-hot matmul kernel, f32
            rounding; see docs/kernels.md).

    Returns:
        Findings frame — ``process`` is the straggler rank, the window is
        that rank's active span.
    """
    return get_backend("stragglers", backend)(trace, threshold=threshold)


def _rank_bounds(proc: np.ndarray, ts: np.ndarray, nprocs: int):
    """Exact per-rank [first, last] event timestamps (int64 ns)."""
    t0 = np.full(nprocs, np.iinfo(np.int64).max, np.int64)
    t1 = np.full(nprocs, np.iinfo(np.int64).min, np.int64)
    np.minimum.at(t0, proc, ts)
    np.maximum.at(t1, proc, ts)
    return t0, t1


@register_backend("stragglers", "numpy")
def _stragglers_numpy(trace, *, threshold: float = 0.2) -> EventFrame:
    ev = trace.events
    nprocs = trace.num_processes
    if len(ev) == 0 or nprocs == 0:
        return Findings([])
    is_enter = ev.cat(ET).mask_eq(ENTER)
    comm = _comm_cat_mask(ev.cat(NAME).categories)[ev.codes(NAME)]
    sel = np.nonzero(is_enter & ~comm)[0]
    work = np.zeros(nprocs)
    proc = np.asarray(ev[PROC], np.int64)
    np.add.at(work, proc[sel],
              np.nan_to_num(np.asarray(ev.column(EXC), np.float64)[sel]))
    t0, t1 = _rank_bounds(proc, np.asarray(ev[TS], np.int64), nprocs)
    return _straggler_findings(work, t0, t1, nprocs, threshold)


@register_backend("stragglers", "pallas")
def _stragglers_pallas(trace, *, threshold: float = 0.2) -> EventFrame:
    """Accelerator stragglers: the per-rank busy sum runs through the
    seg_sum one-hot-matmul kernel over canonically ordered non-comm
    completed calls (f32 rounding; rank time bounds stay exact int64)."""
    ev = trace.events
    nprocs = trace.num_processes
    if len(ev) == 0 or nprocs == 0:
        return Findings([])
    is_enter = ev.cat(ET).mask_eq(ENTER)
    comm = _comm_cat_mask(ev.cat(NAME).categories)[ev.codes(NAME)]
    match = np.asarray(ev.column("_matching_event"), np.int64)
    sel = np.nonzero(is_enter & ~comm & (match >= 0))[0]
    ts = np.asarray(ev[TS], np.float64)
    proc = np.asarray(ev[PROC], np.int64)
    exc = np.nan_to_num(np.asarray(ev.column(EXC), np.float64)[sel])
    _names, _order, inv = accel.alpha_positions(ev.cat(NAME).categories)
    acode = inv[ev.codes(NAME)[sel]]
    o = accel.canonical_order(ts[sel], ts[match[sel]], proc[sel], acode, exc)
    work = accel.seg_sum(proc[sel][o], exc[o], nprocs)
    t0, t1 = _rank_bounds(proc, np.asarray(ev[TS], np.int64), nprocs)
    return _straggler_findings(work, t0, t1, nprocs, threshold)


@register_streaming("stragglers")
class _StragglerAgg(StreamAgg):
    """Per-rank useful-work sums over completed calls plus per-rank time
    bounds — integer-ns, order-independent, cross-worker mergeable."""

    needs_calls = True
    supports_parallel = True

    def __init__(self, threshold: float = 0.2, backend: str = "numpy"):
        get_backend("stragglers", backend)
        if backend not in ("numpy", "pallas"):
            raise StreamingUnsupported(
                f"streaming stragglers supports backends ('numpy', "
                f"'pallas'); {backend!r} is trace-level — materialize with "
                f".collect() to use it")
        self.backend = backend
        self.threshold = float(threshold)
        self._recs: List[tuple] = []
        self._work = np.zeros(0)
        self._t0 = np.full(0, np.iinfo(np.int64).max, np.int64)
        self._t1 = np.full(0, np.iinfo(np.int64).min, np.int64)
        self._classes = _NameClassCache()

    def _bounds(self, ev) -> None:
        if len(ev) == 0:
            return
        proc = np.asarray(ev[PROC], np.int64)
        np_ = int(proc.max()) + 1
        self._t0 = grow_to(self._t0, (np_,), fill=np.iinfo(np.int64).max)
        self._t1 = grow_to(self._t1, (np_,), fill=np.iinfo(np.int64).min)
        ts = np.asarray(ev[TS], np.int64)
        np.minimum.at(self._t0, proc, ts)
        np.maximum.at(self._t1, proc, ts)

    def update(self, chunk) -> None:
        self._bounds(chunk.events)
        calls = chunk.calls
        if calls is None or len(calls.proc) == 0:
            return
        comm = self._classes.mask(chunk.names)[calls.name]
        keep = ~comm
        if not keep.any():
            return
        if self.backend != "numpy":
            self._recs.append((calls.name[keep].copy(),
                               calls.proc[keep].copy(),
                               calls.start[keep].copy(),
                               calls.end[keep].copy(),
                               np.nan_to_num(calls.exc[keep])))
            return
        np_ = int(calls.proc[keep].max()) + 1
        self._work = grow_to(self._work, (np_,))
        np.add.at(self._work, calls.proc[keep], calls.exc[keep])

    def merge_from(self, other, code_map) -> None:
        if self.backend != "numpy":
            for name, proc, start, end, exc in other._recs:
                self._recs.append((code_map[name], proc, start, end, exc))
        np_ = max(len(self._work), len(other._work),
                  len(self._t0), len(other._t0))
        self._work = grow_to(self._work, (np_,))
        self._t0 = grow_to(self._t0, (np_,), fill=np.iinfo(np.int64).max)
        self._t1 = grow_to(self._t1, (np_,), fill=np.iinfo(np.int64).min)
        self._work[:len(other._work)] += other._work
        np.minimum(self._t0[:len(other._t0)], other._t0,
                   out=self._t0[:len(other._t0)])
        np.maximum(self._t1[:len(other._t1)], other._t1,
                   out=self._t1[:len(other._t1)])

    def result(self, ctx) -> EventFrame:
        nprocs = ctx.num_processes
        if nprocs <= 0:
            return Findings([])
        if self.backend != "numpy":
            if self._recs:
                name = np.concatenate([r[0] for r in self._recs])
                proc = np.concatenate([r[1] for r in self._recs])
                start = np.concatenate([r[2] for r in self._recs])
                end = np.concatenate([r[3] for r in self._recs])
                exc = np.concatenate([r[4] for r in self._recs])
            else:
                name = proc = np.zeros(0, np.int64)
                start = end = exc = np.zeros(0)
            _names, _order, inv = accel.alpha_positions(
                ctx.names.names[: len(ctx.names)])
            o = accel.canonical_order(start, end, proc, inv[name], exc)
            work = accel.seg_sum(proc[o], exc[o], nprocs)
        else:
            work = np.zeros(nprocs)
            work[:min(nprocs, len(self._work))] = self._work[:nprocs]
        t0 = np.full(nprocs, np.iinfo(np.int64).max, np.int64)
        t1 = np.full(nprocs, np.iinfo(np.int64).min, np.int64)
        t0[:min(nprocs, len(self._t0))] = self._t0[:nprocs]
        t1[:min(nprocs, len(self._t1))] = self._t1[:nprocs]
        return _straggler_findings(work, t0, t1, nprocs, self.threshold)


# ---------------------------------------------------------------------------
# detector 3: serialization on one thread
# ---------------------------------------------------------------------------

def _serialization_findings(busy, nev, t0, t1, threshold, min_threads):
    rows: List[dict] = []
    nprocs, nthreads = busy.shape
    for p in range(nprocs):
        active = np.nonzero(nev[p] > 0)[0]
        if len(active) < min_threads:
            continue
        b = np.maximum(busy[p, active].astype(np.float64), 0.0)
        total = float(b.sum())
        if total <= 0:
            continue
        k = int(np.argmax(b))
        share = float(b[k]) / total
        nt = len(active)
        sev = (share - 1.0 / nt) / (1.0 - 1.0 / nt)
        if sev >= threshold:
            t = int(active[k])
            rows.append({
                DETECTOR: "serialization",
                LOCATION: f"rank {p} thread {t}",
                F_PROCESS: int(p), F_FUNCTION: "",
                SEVERITY: sev,
                T_START: float(t0[p]), T_END: float(t1[p]),
                EXPLANATION: (
                    f"thread {t} holds {share * 100:.1f}% of rank {p}'s "
                    f"busy time across {nt} threads — work meant to "
                    f"overlap is serialized on one thread"),
            })
    return Findings(rows)


@register_detector("serialization", category="concurrency", threshold=0.85)
def serialization(trace, threshold: float = 0.85,
                  min_threads: int = 2) -> EventFrame:
    """Processes where one thread carries nearly all the busy time.

    Busy time per (process, thread) is the nesting-weighted call time
    ``sum(leave timestamps) - sum(enter timestamps)`` — exact, additive,
    and needing no derived structure.  For processes with at least
    ``min_threads`` active threads, the dominant thread's share is
    normalized against a perfectly-balanced split: severity
    ``(share - 1/T) / (1 - 1/T)`` is 0 when threads share evenly and 1
    when a single thread does everything.  Traces without a thread column
    produce no findings.

    Returns:
        Findings frame — ``process`` is the serialized rank; the location
        names the dominant thread.
    """
    ev = trace.events
    if len(ev) == 0 or THREAD not in ev:
        return Findings([])
    et = ev.cat(ET)
    is_enter = et.mask_eq(ENTER)
    is_leave = et.mask_eq(LEAVE)
    paired = is_enter | is_leave
    proc = np.asarray(ev[PROC], np.int64)
    thread = np.asarray(ev[THREAD], np.int64)
    nprocs = trace.num_processes
    nthreads = int(thread.max()) + 1
    busy = np.zeros((nprocs, nthreads), np.int64)
    nev = np.zeros((nprocs, nthreads), np.int64)
    ts = np.asarray(ev[TS], np.int64)
    sign = np.where(is_leave, 1, -1).astype(np.int64)
    rows = np.nonzero(paired)[0]
    np.add.at(busy, (proc[rows], thread[rows]), ts[rows] * sign[rows])
    np.add.at(nev, (proc[rows], thread[rows]), 1)
    t0 = np.full(nprocs, np.iinfo(np.int64).max, np.int64)
    t1 = np.full(nprocs, np.iinfo(np.int64).min, np.int64)
    np.minimum.at(t0, proc, ts)
    np.maximum.at(t1, proc, ts)
    return _serialization_findings(busy, nev, t0, t1, threshold, min_threads)


@register_streaming("serialization")
class _SerializationAgg(StreamAgg):
    """Signed-timestamp accumulation per (process, thread): each chunk adds
    ``sum(leave ts) - sum(enter ts)`` — int64-exact and order-independent,
    so chunk boundaries and worker merges cannot change the result."""

    supports_parallel = True

    def __init__(self, threshold: float = 0.85, min_threads: int = 2):
        self.threshold = float(threshold)
        self.min_threads = int(min_threads)
        self._busy = np.zeros((0, 0), np.int64)
        self._nev = np.zeros((0, 0), np.int64)
        self._t0 = np.full(0, np.iinfo(np.int64).max, np.int64)
        self._t1 = np.full(0, np.iinfo(np.int64).min, np.int64)

    def update(self, chunk) -> None:
        ev = chunk.events
        if len(ev) == 0 or THREAD not in ev:
            return
        proc = np.asarray(ev[PROC], np.int64)
        ts = np.asarray(ev[TS], np.int64)
        np_ = int(proc.max()) + 1
        self._t0 = grow_to(self._t0, (np_,), fill=np.iinfo(np.int64).max)
        self._t1 = grow_to(self._t1, (np_,), fill=np.iinfo(np.int64).min)
        np.minimum.at(self._t0, proc, ts)
        np.maximum.at(self._t1, proc, ts)
        et = ev.cat(ET)
        is_enter = et.mask_eq(ENTER)
        is_leave = et.mask_eq(LEAVE)
        rows = np.nonzero(is_enter | is_leave)[0]
        if len(rows) == 0:
            return
        thread = np.asarray(ev[THREAD], np.int64)
        nt = int(thread[rows].max()) + 1
        self._busy = grow_to(self._busy, (np_, nt))
        self._nev = grow_to(self._nev, (np_, nt))
        sign = np.where(is_leave[rows], 1, -1).astype(np.int64)
        np.add.at(self._busy, (proc[rows], thread[rows]), ts[rows] * sign)
        np.add.at(self._nev, (proc[rows], thread[rows]), 1)

    def merge_from(self, other, code_map) -> None:
        shape = (max(self._busy.shape[0], other._busy.shape[0]),
                 max(self._busy.shape[1], other._busy.shape[1]))
        self._busy = grow_to(self._busy, shape)
        self._nev = grow_to(self._nev, shape)
        op, ot = other._busy.shape
        self._busy[:op, :ot] += other._busy
        self._nev[:op, :ot] += other._nev
        np_ = max(len(self._t0), len(other._t0))
        self._t0 = grow_to(self._t0, (np_,), fill=np.iinfo(np.int64).max)
        self._t1 = grow_to(self._t1, (np_,), fill=np.iinfo(np.int64).min)
        np.minimum(self._t0[:len(other._t0)], other._t0,
                   out=self._t0[:len(other._t0)])
        np.maximum(self._t1[:len(other._t1)], other._t1,
                   out=self._t1[:len(other._t1)])

    def result(self, ctx) -> EventFrame:
        nprocs = ctx.num_processes
        if nprocs <= 0 or self._nev.size == 0:
            return Findings([])
        nthreads = self._nev.shape[1]
        busy = np.zeros((nprocs, nthreads), np.int64)
        nev = np.zeros((nprocs, nthreads), np.int64)
        p = min(nprocs, self._busy.shape[0])
        busy[:p] = self._busy[:p, :nthreads]
        nev[:p] = self._nev[:p, :nthreads]
        t0 = np.full(nprocs, np.iinfo(np.int64).max, np.int64)
        t1 = np.full(nprocs, np.iinfo(np.int64).min, np.int64)
        t0[:min(nprocs, len(self._t0))] = self._t0[:nprocs]
        t1[:min(nprocs, len(self._t1))] = self._t1[:nprocs]
        return _serialization_findings(busy, nev, t0, t1, self.threshold,
                                       self.min_threads)


# ---------------------------------------------------------------------------
# detector 4: load-imbalance root cause
# ---------------------------------------------------------------------------

def _imbalance_findings(names, tot, nprocs, t0, t1, threshold, top_n):
    rows: List[dict] = []
    if nprocs <= 0 or tot.size == 0:
        return Findings(rows)
    mean_work = float(tot.sum()) / nprocs
    if mean_work <= 0:
        return Findings(rows)
    per_mean = tot.sum(axis=1) / nprocs
    per_max = tot.max(axis=1)
    culprit = np.argmax(tot, axis=1)
    cost = per_max - per_mean
    sev = cost / mean_work
    order = np.argsort(-sev, kind="stable")
    if top_n is not None:
        order = order[:top_n]
    for f in order:
        if sev[f] < threshold:
            break
        p = int(culprit[f])
        ratio = (float(per_max[f]) / per_mean[f]) if per_mean[f] > 0 else 0.0
        rows.append({
            DETECTOR: "imbalance_root_cause",
            LOCATION: f"{names[f]} @ rank {p}",
            F_PROCESS: p, F_FUNCTION: str(names[f]),
            SEVERITY: float(sev[f]),
            T_START: float(t0), T_END: float(t1),
            EXPLANATION: (
                f"{names[f]} is {ratio:.2f}x imbalanced: rank {p} spends "
                f"{_ms(float(per_max[f]))} vs a {_ms(float(per_mean[f]))} "
                f"cross-rank mean — {_ms(float(cost[f]))} of imbalance "
                f"cost ({sev[f] * 100:.1f}% of mean rank work)"),
        })
    return Findings(rows)


@register_detector("imbalance_root_cause", category="imbalance",
                   threshold=0.05, needs_structure=True)
def imbalance_root_cause(trace, threshold: float = 0.05,
                         metric: str = EXC,
                         top_n: Optional[int] = None) -> EventFrame:
    """Which functions drive load imbalance, and on which rank.

    For every function, sums the metric per rank; the imbalance *cost* of a
    function is ``max-over-ranks - mean-over-ranks`` (the time the busiest
    rank makes everyone else wait, were they to synchronize).  Severity
    normalizes that cost by the mean per-rank total work, so 0.10 means
    this one function costs 10% of a rank's work in imbalance.

    Args:
        threshold: minimum severity to report.
        metric: ``time.exc`` (default) or ``time.inc``.
        top_n: report at most N functions (None = all above threshold).

    Returns:
        Findings frame — ``function`` names the root cause, ``process``
        the dominant rank.
    """
    ev = trace.events
    nprocs = trace.num_processes
    if len(ev) == 0 or nprocs == 0:
        return Findings([])
    ent = np.nonzero(ev.cat(ET).mask_eq(ENTER))[0]
    vals = np.nan_to_num(np.asarray(ev.column(metric), np.float64)[ent])
    names = ev.codes(NAME)[ent]
    procs = np.asarray(ev[PROC], np.int64)[ent]
    cats = [str(c) for c in ev.cat(NAME).categories]
    tot = np.zeros((len(cats), nprocs))
    np.add.at(tot, (names, procs), vals)
    ts = np.asarray(ev[TS], np.int64)
    return _imbalance_findings(cats, tot, nprocs, int(ts.min()),
                               int(ts.max()), threshold, top_n)


@register_streaming("imbalance_root_cause")
class _ImbalanceRootCauseAgg(StreamAgg):
    """Per-(function, rank) metric sums over completed calls — the
    load_imbalance accumulator with a findings finalizer."""

    needs_calls = True
    supports_parallel = True

    def __init__(self, threshold: float = 0.05, metric: str = EXC,
                 top_n: Optional[int] = None):
        if metric not in (INC, EXC):
            raise StreamingUnsupported(
                f"streaming imbalance_root_cause supports metrics "
                f"{(INC, EXC)}, got {metric!r}")
        self.threshold = float(threshold)
        self.metric = metric
        self.top_n = top_n
        self._tot = np.zeros((0, 0))
        self._t0 = np.iinfo(np.int64).max
        self._t1 = np.iinfo(np.int64).min

    def update(self, chunk) -> None:
        ev = chunk.events
        if len(ev):
            ts = np.asarray(ev[TS], np.int64)
            self._t0 = min(self._t0, int(ts.min()))
            self._t1 = max(self._t1, int(ts.max()))
        calls = chunk.calls
        nf = len(chunk.names)
        if calls is None or len(calls.proc) == 0:
            return
        np_ = int(calls.proc.max()) + 1
        self._tot = grow_to(self._tot, (nf, np_))
        vals = calls.exc if self.metric == EXC else calls.inc
        np.add.at(self._tot, (calls.name, calls.proc), vals)

    def merge_from(self, other, code_map) -> None:
        from .ops_summary import _scatter_names
        self._tot = _scatter_names(self._tot, other._tot, code_map, axis=0)
        self._t0 = min(self._t0, other._t0)
        self._t1 = max(self._t1, other._t1)

    def result(self, ctx) -> EventFrame:
        nf = len(ctx.names)
        nprocs = ctx.num_processes
        if nf == 0 or nprocs <= 0:
            return Findings([])
        from .ops_summary import _pad_to
        tot = _pad_to(self._tot, (nf, nprocs))
        return _imbalance_findings(ctx.names.names, tot, nprocs, self._t0,
                                   self._t1, self.threshold, self.top_n)


# ---------------------------------------------------------------------------
# detector 5: time-resolved POP efficiency
# ---------------------------------------------------------------------------

def _window_edges(t0: int, t1: int, num_windows: int) -> np.ndarray:
    """Integer window edges over [t0, t1] — exact and identical however
    the bounds were obtained (eager min/max or the streaming stats pass)."""
    span = max(int(t1) - int(t0), 1)
    k = np.arange(num_windows + 1, dtype=np.int64)
    return int(t0) + (span * k) // num_windows


def _efficiency_frame(edges, useful, comm, nprocs) -> EventFrame:
    """Per-window POP metrics from exact per-(window, rank) ns sums.

    * load-balance efficiency = mean-over-ranks / max-over-ranks useful ns
    * communication efficiency = useful ns / (useful + communication) ns
    * parallel efficiency = the product

    Windows with no activity report 1.0 across the board (nothing ran, so
    nothing was inefficient).  Each call's exclusive time is attributed to
    the window containing its Enter timestamp (the ``activity_series``
    convention), keeping every sum integer-exact.
    """
    nw = len(edges) - 1
    u_mean = useful.sum(axis=1) / max(nprocs, 1)
    u_max = useful.max(axis=1) if nprocs else np.zeros(nw)
    busy = useful.sum(axis=1) + comm.sum(axis=1)
    lb = np.where(u_max > 0, u_mean / np.maximum(u_max, 1e-30), 1.0)
    ce = np.where(busy > 0, useful.sum(axis=1) / np.maximum(busy, 1e-30),
                  1.0)
    pe = lb * ce
    return EventFrame({
        "window": np.arange(nw, dtype=np.int64),
        T_START: edges[:-1].astype(np.float64),
        T_END: edges[1:].astype(np.float64),
        "parallel_eff": np.clip(pe, 0.0, 1.0),
        "load_balance_eff": np.clip(lb, 0.0, 1.0),
        "comm_eff": np.clip(ce, 0.0, 1.0),
        "useful_ns": useful.sum(axis=1),
        "comm_ns": comm.sum(axis=1),
    })


def _accumulate_windows(edges, start, proc, exc, comm_mask, nprocs):
    nw = len(edges) - 1
    useful = np.zeros((nw, nprocs))
    comm = np.zeros((nw, nprocs))
    w = np.clip(np.searchsorted(edges, start, side="right") - 1, 0, nw - 1)
    np.add.at(useful, (w[~comm_mask], proc[~comm_mask]), exc[~comm_mask])
    np.add.at(comm, (w[comm_mask], proc[comm_mask]), exc[comm_mask])
    return useful, comm


@register_op("efficiency_metrics", needs_structure=True)
def efficiency_metrics(trace, num_windows: int = 16) -> EventFrame:
    """Time-resolved POP efficiency metrics (arxiv 2512.01764).

    Splits the trace span into ``num_windows`` equal windows and reports,
    per window, parallel / load-balance / communication efficiency — all
    in [0, 1] — plus the raw useful and communication ns.  Each call's
    exclusive time counts toward the window containing its Enter timestamp
    and is classed communication or useful by name
    (:func:`is_comm_name`).

    Returns:
        EventFrame with ``window``, ``t_start``, ``t_end``,
        ``parallel_eff``, ``load_balance_eff``, ``comm_eff``,
        ``useful_ns``, ``comm_ns`` — one row per window, in time order.
    """
    ev = trace.events
    nprocs = trace.num_processes
    num_windows = int(num_windows)
    if len(ev) == 0 or nprocs == 0 or num_windows <= 0:
        return _efficiency_frame(np.asarray([0, 1], np.int64),
                                 np.zeros((1, 1)), np.zeros((1, 1)), 1)
    ts = np.asarray(ev[TS], np.int64)
    edges = _window_edges(int(ts.min()), int(ts.max()), num_windows)
    ent = np.nonzero(ev.cat(ET).mask_eq(ENTER))[0]
    exc = np.nan_to_num(np.asarray(ev.column(EXC), np.float64)[ent])
    comm = _comm_cat_mask(ev.cat(NAME).categories)[ev.codes(NAME)[ent]]
    useful, comm_t = _accumulate_windows(
        edges, ts[ent], np.asarray(ev[PROC], np.int64)[ent], exc, comm,
        nprocs)
    return _efficiency_frame(edges, useful, comm_t, nprocs)


def _pop_findings(metrics: EventFrame, threshold: float) -> EventFrame:
    rows: List[dict] = []
    pe = np.asarray(metrics["parallel_eff"], np.float64)
    busy = (np.asarray(metrics["useful_ns"], np.float64)
            + np.asarray(metrics["comm_ns"], np.float64))
    active = busy > 0
    if not active.any():
        return Findings(rows)
    med = float(np.median(pe[active]))
    if med <= 0:
        return Findings(rows)
    lb = np.asarray(metrics["load_balance_eff"], np.float64)
    ce = np.asarray(metrics["comm_eff"], np.float64)
    t0 = np.asarray(metrics[T_START], np.float64)
    t1 = np.asarray(metrics[T_END], np.float64)
    win = np.asarray(metrics["window"], np.int64)
    for i in np.nonzero(active)[0]:
        sev = max(0.0, (med - float(pe[i])) / med)
        if sev >= threshold:
            rows.append({
                DETECTOR: "pop_efficiency",
                LOCATION: f"window {int(win[i])}",
                F_PROCESS: -1, F_FUNCTION: "",
                SEVERITY: sev,
                T_START: float(t0[i]), T_END: float(t1[i]),
                EXPLANATION: (
                    f"window {int(win[i])} parallel efficiency "
                    f"{pe[i] * 100:.1f}% vs a {med * 100:.1f}% trace "
                    f"median (load balance {lb[i] * 100:.1f}%, "
                    f"communication {ce[i] * 100:.1f}%)"),
            })
    return Findings(rows)


@register_detector("pop_efficiency", category="efficiency", threshold=0.1,
                   needs_structure=True)
def pop_efficiency(trace, threshold: float = 0.1,
                   num_windows: int = 16) -> EventFrame:
    """Time windows whose parallel efficiency collapses below the trace's
    own median.

    Computes :func:`efficiency_metrics` and flags every active window
    whose parallel efficiency falls relatively ``threshold`` below the
    median over active windows — a self-calibrating gate, so steady
    (even steadily-mediocre) traces produce no findings and genuine
    phase-local drops stand out.

    Returns:
        Findings frame — one row per flagged window, with the POP metrics
        spelled out in the explanation.
    """
    return _pop_findings(efficiency_metrics(trace, num_windows=num_windows),
                         threshold)


class _EfficiencyMetricsAgg(StreamAgg):
    """Streaming :func:`efficiency_metrics`: global window edges from the
    stats pre-pass, then exact per-(window, rank) useful/comm ns sums over
    completed calls."""

    needs_calls = True
    needs_stats = True
    supports_parallel = True

    def __init__(self, num_windows: int = 16):
        self.num_windows = int(num_windows)
        self._edges: Optional[np.ndarray] = None
        self._useful = np.zeros((max(self.num_windows, 1), 0))
        self._comm = np.zeros((max(self.num_windows, 1), 0))
        self._classes = _NameClassCache()

    def begin(self, stats) -> None:
        if stats is not None and stats.n_events > 0 and self.num_windows > 0:
            self._edges = _window_edges(int(stats.ts_min),
                                        int(stats.ts_max), self.num_windows)

    def update(self, chunk) -> None:
        calls = chunk.calls
        if self._edges is None or calls is None or len(calls.proc) == 0:
            return
        np_ = int(calls.proc.max()) + 1
        self._useful = grow_to(self._useful, (self.num_windows, np_))
        self._comm = grow_to(self._comm, (self.num_windows, np_))
        comm = self._classes.mask(chunk.names)[calls.name]
        start = np.asarray(calls.start, np.int64)
        w = np.clip(np.searchsorted(self._edges, start, side="right") - 1,
                    0, self.num_windows - 1)
        np.add.at(self._useful, (w[~comm], calls.proc[~comm]),
                  calls.exc[~comm])
        np.add.at(self._comm, (w[comm], calls.proc[comm]), calls.exc[comm])

    def merge_from(self, other, code_map) -> None:
        np_ = max(self._useful.shape[1], other._useful.shape[1])
        self._useful = grow_to(self._useful, (self.num_windows, np_))
        self._comm = grow_to(self._comm, (self.num_windows, np_))
        ow = other._useful.shape[1]
        self._useful[:, :ow] += other._useful
        self._comm[:, :ow] += other._comm

    def _metrics(self, ctx) -> EventFrame:
        nprocs = ctx.num_processes
        if self._edges is None or nprocs <= 0:
            return _efficiency_frame(np.asarray([0, 1], np.int64),
                                     np.zeros((1, 1)), np.zeros((1, 1)), 1)
        from .ops_summary import _pad_to
        useful = _pad_to(self._useful, (self.num_windows, nprocs))
        comm = _pad_to(self._comm, (self.num_windows, nprocs))
        return _efficiency_frame(self._edges, useful, comm, nprocs)

    def result(self, ctx) -> EventFrame:
        return self._metrics(ctx)


register_streaming("efficiency_metrics")(_EfficiencyMetricsAgg)


@register_streaming("pop_efficiency")
class _PopEfficiencyAgg(_EfficiencyMetricsAgg):
    """Streaming :func:`pop_efficiency`: the metrics aggregator with the
    findings finalizer."""

    def __init__(self, threshold: float = 0.1, num_windows: int = 16):
        super().__init__(num_windows=num_windows)
        self.threshold = float(threshold)

    def result(self, ctx) -> EventFrame:
        return _pop_findings(self._metrics(ctx), self.threshold)


# ---------------------------------------------------------------------------
# diagnose: run every detector, one combined ranked report
# ---------------------------------------------------------------------------

def _resolve_detectors(detectors) -> List[str]:
    if detectors is None:
        return list_detectors()
    names = [str(d) for d in detectors]
    for d in names:
        if d not in _DETECTOR_REGISTRY:
            raise ValueError(f"unknown detector {d!r}; registered: "
                             f"{list_detectors()}")
    return sorted(set(names))


def _rank_findings(frames: Sequence[EventFrame]) -> EventFrame:
    """Concatenate per-detector Findings into one ranked report (same
    deterministic total order :func:`Findings` uses)."""
    rows: List[dict] = []
    for fr in frames:
        for i in range(len(fr)):
            rows.append({c: fr[c][i] for c in FINDINGS_COLUMNS})
    return Findings(rows)


@register_op("diagnose", needs_structure=True, needs_messages=True)
def diagnose(trace, detectors: Optional[Sequence[str]] = None) -> EventFrame:
    """Run every registered detector (or a named subset) and return one
    combined, severity-ranked Findings frame.

    Each detector runs with its default arguments; tune an individual
    detector by calling its op directly
    (``trace.query().stragglers(threshold=0.1)``).

    Args:
        detectors: detector names to run (None = all registered).

    Returns:
        Findings frame over all selected detectors, ranked by severity
        descending — the ``detector`` column says which check fired.
    """
    names = _resolve_detectors(detectors)
    return _rank_findings([_DETECTOR_REGISTRY[d].fn(trace) for d in names])


@register_streaming("diagnose")
class _DiagnoseAgg(StreamAgg):
    """Composite aggregator: one child aggregator per selected detector,
    all fed from the same single pass over the stream (stats pre-pass and
    call stitching are shared).  Parallel-safe because every built-in
    detector's child merges across workers."""

    needs_calls = True
    needs_stats = True
    supports_parallel = True

    def __init__(self, detectors: Optional[Sequence[str]] = None):
        from . import registry as _registry
        self._names = _resolve_detectors(detectors)
        self._children: List[StreamAgg] = []
        for d in self._names:
            spec = _registry.get_op(d)
            if spec is None or spec.streaming is None:
                raise StreamingUnsupported(
                    f"detector {d!r} has no streaming form; materialize "
                    f"with .collect().diagnose(...) or run it eagerly")
            self._children.append(spec.streaming())

    def begin(self, stats) -> None:
        for c in self._children:
            c.begin(stats)

    def update(self, chunk) -> None:
        for c in self._children:
            c.update(chunk)

    def merge_from(self, other, code_map) -> None:
        for mine, theirs in zip(self._children, other._children):
            mine.merge_from(theirs, code_map)

    def result(self, ctx) -> EventFrame:
        return _rank_findings([c.result(ctx) for c in self._children])
