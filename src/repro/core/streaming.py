"""Out-of-core streaming execution of lazy query plans (paper §VI scaled).

The paper's core critique of GUI trace tools — "challenging to scale to
large trace sizes" — applies equally to any engine that must materialize a
whole trace before the first analysis op runs.  This module executes
:class:`~repro.core.query.TraceQuery` plans over traces that do not fit in
RAM:

* readers expose ``iter_chunks(path, chunk_rows, hints)`` in the reader
  registry (:class:`~repro.core.registry.ReaderSpec`), yielding bounded
  EventFrames with the plan's predicate/process/time-window restriction
  pushed down (:class:`~repro.core.registry.PlanHints`);
* the executor applies the plan's **fused mask** to each chunk (one boolean
  AND per chunk, exactly like the in-memory fusion path) and feeds the
  surviving rows to the terminal op's **streaming aggregator** — a
  combinable partial-aggregate form registered next to the op with
  :func:`~repro.core.registry.register_streaming`;
* structure-dependent aggregates (flat/time profiles, load imbalance, idle
  time) are fed **completed-call records** stitched across chunk boundaries
  by :class:`CallStitcher`: within-chunk enter/leave pairs are matched with
  the same vectorized kernel the in-memory path uses, and the few calls
  split across a boundary (an open ``main()`` spans *every* boundary) are
  carried on per-(process, thread) stacks until their leave arrives — the
  boundary-stitching path for pairs split across chunks;
* ops with no combinable form (``detect_pattern``,
  ``critical_path_analysis``, ...) raise :class:`StreamingUnsupported`
  with the escape hatches spelled out instead of silently loading the
  trace.

Entry points: ``Trace.open(path, streaming=True)`` returns a
:class:`StreamingTrace`; ``trace.query()...<op>()`` then executes out of
core.  See ``docs/streaming.md`` for the execution model and guarantees.
"""

from __future__ import annotations

import copy
import threading
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from . import registry, structure
from .constants import (DERIVED_COLUMNS, ENTER, ET, EXC, INC, LEAVE, MATCH,
                        NAME, PARENT, PROC, THREAD, TS)
from .frame import Categorical, EventFrame, concat

__all__ = ["StreamingTrace", "LiveTrace", "Watermark", "LiveResult",
           "StreamingUnsupported", "StreamAgg",
           "CallBlock", "Chunk", "StreamStats", "StreamContext",
           "execute_streaming", "iter_chunks_fallback", "grow_to",
           "fold_frames", "mask_frames", "stats_from_frames"]

DEFAULT_CHUNK_ROWS = 1_000_000


class StreamingUnsupported(RuntimeError):
    """A plan or op has no out-of-core form.  The message always names the
    escape hatches: ``.collect()`` (materialize, then run eagerly) or
    ``Trace.open(..., streaming=False)``."""


# ---------------------------------------------------------------------------
# shared name space across chunks
# ---------------------------------------------------------------------------

class GlobalNames:
    """Interner mapping every chunk's local Categorical onto one stable
    global code space (codes are assigned in first-seen order; results that
    need the in-memory alphabetical order sort at finalize time)."""

    def __init__(self):
        self._code: Dict[str, int] = {}
        self.names: List[str] = []

    def encode(self, cat: Categorical) -> np.ndarray:
        """Global int64 code per row of ``cat``."""
        local = np.empty(len(cat.categories), np.int64)
        for i, c in enumerate(cat.categories):
            local[i] = self.intern(str(c))
        return local[cat.codes]

    def intern(self, name: str) -> int:
        """Code of ``name``, assigning the next one on first sight — the
        parallel executor merges worker name spaces through this, in unit
        order, reproducing the serial first-seen code assignment."""
        g = self._code.get(name)
        if g is None:
            g = len(self.names)
            self._code[name] = g
            self.names.append(name)
        return g

    def code(self, name: str) -> int:
        """Global code of ``name``, or -1 when never seen."""
        return self._code.get(str(name), -1)

    def __len__(self) -> int:
        return len(self.names)


def grow_to(arr: np.ndarray, shape: Tuple[int, ...], fill=0) -> np.ndarray:
    """Return ``arr`` grown (power-of-two per axis) to hold ``shape`` —
    the accumulator pattern streaming aggregators use while the name/process
    universe is still being discovered."""
    target = []
    need = False
    for have, want in zip(arr.shape, shape):
        if want > have:
            cap = max(have, 1)
            while cap < want:
                cap *= 2
            target.append(cap)
            need = True
        else:
            target.append(have)
    if not need:
        return arr
    out = np.full(tuple(target), fill, dtype=arr.dtype)
    out[tuple(slice(0, n) for n in arr.shape)] = arr
    return out


# ---------------------------------------------------------------------------
# chunk payloads
# ---------------------------------------------------------------------------

class CallBlock:
    """Completed calls discovered in one chunk: one entry per call whose
    Leave arrived (whether its Enter was in this chunk or carried over)."""

    __slots__ = ("name", "proc", "start", "end", "inc", "exc")

    def __init__(self, name, proc, start, end, inc, exc):
        self.name = name      # global name codes (int64)
        self.proc = proc      # int64
        self.start = start    # float64 enter timestamps
        self.end = end        # float64 leave timestamps
        self.inc = inc        # float64 inclusive ns
        self.exc = exc        # float64 exclusive ns


class Chunk:
    """What an aggregator sees per chunk: the masked frame, its rows' global
    name codes, and (when requested) the completed-call block."""

    __slots__ = ("events", "gcodes", "calls", "names")

    def __init__(self, events: EventFrame, gcodes: np.ndarray,
                 calls: Optional[CallBlock], names: GlobalNames):
        self.events = events
        self.gcodes = gcodes
        self.calls = calls
        self.names = names


class StreamStats:
    """Global pre-pass statistics over the masked stream (two-pass ops)."""

    __slots__ = ("n_events", "ts_min", "ts_max", "proc_max", "size_min",
                 "size_max", "n_sends")

    def __init__(self):
        self.n_events = 0
        self.ts_min = np.inf
        self.ts_max = -np.inf
        self.proc_max = -1
        self.size_min = np.inf
        self.size_max = -np.inf
        self.n_sends = 0

    @property
    def num_processes(self) -> int:
        return self.proc_max + 1

    def merge(self, other: "StreamStats") -> None:
        """Fold another partial stats pass in — all fields are mins/maxes
        or integer sums, so merging is exact and order-independent (the
        parallel stats pre-pass relies on this)."""
        self.n_events += other.n_events
        self.ts_min = min(self.ts_min, other.ts_min)
        self.ts_max = max(self.ts_max, other.ts_max)
        self.proc_max = max(self.proc_max, other.proc_max)
        self.size_min = min(self.size_min, other.size_min)
        self.size_max = max(self.size_max, other.size_max)
        self.n_sends += other.n_sends


class StreamAgg:
    """Base class for streaming aggregators.

    Subclasses declare what they consume and implement the three-phase
    protocol; the executor guarantees ``begin`` → ``update``\\* → ``result``.
    ``needs_stats`` triggers a dedicated first pass over the masked stream
    (the stream is re-read — CPU doubles, peak memory stays bounded).

    Aggregators whose partial state also merges *across workers* set
    ``supports_parallel = True`` and implement :meth:`merge_from`; the
    multi-core executor (:mod:`repro.core.executor`) fans exactly those over
    a process pool and runs everything else serially (with a warning naming
    the op).
    """

    needs_calls = False   # completed-call records (structure across chunks)
    needs_stats = False   # StreamStats pre-pass
    #: declared by subclasses whose merge_from makes multi-core fan-out safe
    supports_parallel = False

    def begin(self, stats: Optional[StreamStats]) -> None:
        pass

    def update(self, chunk: Chunk) -> None:
        raise NotImplementedError

    def result(self, ctx: "StreamContext") -> Any:
        raise NotImplementedError

    def merge_from(self, other: "StreamAgg", code_map: np.ndarray) -> None:
        """Fold a worker aggregator's partial state into this one.

        ``other`` is the same aggregator class updated over one work unit;
        ``code_map[c]`` is the merged global name code for the worker's
        local code ``c`` (len == the worker's name-table size).  Only called
        when ``supports_parallel`` is True.
        """
        raise StreamingUnsupported(
            f"{type(self).__name__} declares no cross-worker merge; the op "
            f"cannot run under the parallel executor")


class StreamContext:
    """Finalization context: the global name table, pre-pass stats (if any),
    and the (name code, process) pairs of calls left open at end of stream
    (their Leave never arrived — the in-memory path's unmatched enters)."""

    __slots__ = ("names", "stats", "open_calls", "proc_max")

    def __init__(self, names: GlobalNames, stats: Optional[StreamStats],
                 open_calls: Tuple[np.ndarray, np.ndarray], proc_max: int):
        self.names = names
        self.stats = stats
        self.open_calls = open_calls
        self.proc_max = proc_max

    @property
    def num_processes(self) -> int:
        return self.proc_max + 1


# ---------------------------------------------------------------------------
# cross-chunk call stitching
# ---------------------------------------------------------------------------

class _Frame:
    """One open call carried across chunk boundaries."""

    __slots__ = ("name", "proc", "start", "child_inc")

    def __init__(self, name: int, proc: int, start: float):
        self.name = name
        self.proc = proc
        self.start = start
        self.child_inc = 0.0


class CallStitcher:
    """Turns a sorted chunk stream into completed-call records, stitching
    enter/leave pairs split across chunk boundaries.

    Within a chunk, pairs are matched with the same vectorized kernel the
    in-memory path uses (:func:`repro.core.structure.match_events`) and
    their inclusive/exclusive times come from the canonical
    :func:`~repro.core.structure.compute_inc_exc` — all direct children of a
    within-chunk call are provably inside the chunk, so those values are
    exact.  Events the chunk cannot resolve are exactly the boundary ones:
    an Enter whose Leave is in a later chunk is pushed on a per-(process,
    thread) carry stack; an unmatched Leave pops the innermost open carried
    call and completes it.  Exclusive time of a carried call is its
    inclusive time minus the child time accumulated on its stack frame —
    chunk-level top calls are bucket-summed onto the innermost open frame
    between boundary events, so no per-event Python loop ever runs.

    Requires each (process, thread) sub-stream to arrive in non-decreasing
    time order (trace files written per-rank or in canonical (process,
    time) order satisfy this); violations raise StreamingUnsupported.

    ``defer_unmatched=True`` is the parallel-worker mode: events this
    stream prefix cannot resolve (a Leave whose Enter lives in an earlier
    work unit, and chunk-top call time that belongs to a call opened
    upstream) are *recorded as seam events* instead of being dropped, and
    the parent executor replays them against the carry stacks of the
    preceding units — the cross-seam half of stitch-safe partitioning.
    """

    def __init__(self, defer_unmatched: bool = False):
        self._stacks: Dict[int, List[_Frame]] = {}
        self._last_ts: Dict[int, float] = {}
        self._first_ts: Dict[int, float] = {}
        self._defer = defer_unmatched
        # per group, in event order: ("a", inc) = attribute inc to the
        # innermost call open upstream; ("l", ts, proc) = a Leave closing
        # the innermost call open upstream
        self._seams: Dict[int, List[tuple]] = {}

    # -- public ------------------------------------------------------------
    def push_chunk(self, ev: EventFrame, gcodes: np.ndarray) -> CallBlock:
        n = len(ev)
        if n == 0:
            return CallBlock(*[np.empty(0, np.int64)] * 2,
                             *[np.empty(0, np.float64)] * 4)
        gkey = self._group_key_rows(ev)
        ts = np.asarray(ev[TS], np.float64)
        self._check_sorted(gkey, ts)

        pre = self._precomputed(ev)
        if pre is not None:
            matching, parent, inc, exc = pre
        else:
            matching, _depth, parent, inc, exc = structure.derive_structure(ev)

        et = ev.cat(ET)
        is_enter = et.mask_eq(ENTER)
        is_leave = et.mask_eq(LEAVE)
        procs = np.asarray(ev[PROC], np.int64)

        matched_ent = np.nonzero(is_enter & (matching >= 0))[0]
        # chunk-level top calls: matched calls whose parent the chunk cannot
        # see — their inclusive time belongs to the innermost open carried
        # call at their position
        top_ent = matched_ent[parent[matched_ent] < 0]

        boundary = np.nonzero((is_enter | is_leave) & (matching < 0))[0]
        # matched calls whose parent is a *boundary enter of this chunk*
        # (the parent's own exc is NaN here — its frame is pushed below):
        # credit their inclusive time straight onto that frame
        par = parent[matched_ent]
        has_par = par >= 0
        bp = matched_ent[has_par]
        bp = bp[(matching[parent[bp]] < 0) & is_enter[parent[bp]]]
        pending_child = {}
        if len(bp):
            add = np.zeros(n)
            np.add.at(add, parent[bp], inc[bp])
            pending_child = {int(r): float(add[r])
                             for r in np.unique(parent[bp])}
        carried = self._stitch(gkey, gcodes, ts, procs, is_enter,
                               boundary, top_ent, inc, pending_child)

        name = gcodes[matched_ent]
        proc = procs[matched_ent]
        start = ts[matched_ent]
        end = ts[matching[matched_ent]]
        binc = inc[matched_ent]
        bexc = exc[matched_ent]
        if carried:
            cn, cp, cs, ce, ci, cx = (np.asarray(c) for c in zip(*carried))
            name = np.concatenate([name, cn.astype(np.int64)])
            proc = np.concatenate([proc, cp.astype(np.int64)])
            start = np.concatenate([start, cs])
            end = np.concatenate([end, ce])
            binc = np.concatenate([binc, ci])
            bexc = np.concatenate([bexc, cx])
        return CallBlock(name, proc, start, end, binc, bexc)

    def open_calls(self) -> Tuple[np.ndarray, np.ndarray]:
        """(global name codes, process ids) of calls still open at end of
        stream — their Leave never arrived, i.e. the in-memory matcher's
        unmatched enters."""
        frames = [f for st in self._stacks.values() for f in st]
        return (np.asarray([f.name for f in frames], np.int64),
                np.asarray([f.proc for f in frames], np.int64))

    # -- parallel-worker exports -------------------------------------------
    def seams(self) -> Dict[int, List[tuple]]:
        """Per-group seam events deferred to upstream units (worker mode)."""
        return self._seams

    def trailing(self) -> Dict[int, List[Tuple[int, int, float, float]]]:
        """Per-group open frames at end of this unit, innermost last:
        (name code, proc, start ts, accumulated child inclusive ns)."""
        return {g: [(f.name, f.proc, f.start, f.child_inc) for f in st]
                for g, st in self._stacks.items() if st}

    def group_span(self) -> Tuple[Dict[int, float], Dict[int, float]]:
        """Per-group (first, last) event timestamps seen — the parent
        executor checks cross-unit time order with these."""
        return dict(self._first_ts), dict(self._last_ts)

    # -- internals -----------------------------------------------------------
    def _check_sorted(self, gkey: np.ndarray, ts: np.ndarray) -> None:
        order = np.lexsort((np.arange(len(gkey)), gkey))
        g_s, t_s = gkey[order], ts[order]
        same = g_s[1:] == g_s[:-1]
        if np.any(same & (np.diff(t_s) < 0)):
            raise StreamingUnsupported(
                "streaming execution needs each (process, thread) event "
                "stream in non-decreasing time order within a chunk; this "
                "trace is not sorted.  Re-shard it (e.g. "
                "readers.parallel.split_jsonl_by_process) or open with "
                "streaming=False.")
        firsts = np.nonzero(np.concatenate([[True], ~same]))[0]
        for i in firsts:
            g = int(g_s[i])
            if g not in self._first_ts:
                self._first_ts[g] = float(t_s[i])
            last = self._last_ts.get(g)
            if last is not None and t_s[i] < last:
                raise StreamingUnsupported(
                    "streaming execution needs each (process, thread) event "
                    "stream in non-decreasing time order across chunks; "
                    "this trace interleaves out of order.  Re-shard it or "
                    "open with streaming=False.")
        # record per-group max ts of this chunk
        lasts = np.nonzero(np.concatenate([~same, [True]]))[0]
        for i in lasts:
            self._last_ts[int(g_s[i])] = float(t_s[i])

    def _stitch(self, gkey, gcodes, ts, procs, is_enter, boundary,
                top_ent, inc, pending_child) -> List[tuple]:
        """Walk boundary events per group in row order, bucket-attributing
        chunk-top call time to the innermost open carried frame."""
        completed: List[tuple] = []
        if len(boundary) == 0 and not self._stacks:
            return completed
        # bucket chunk-top calls between boundary events, per group
        by_group_b: Dict[int, np.ndarray] = {}
        for g in np.unique(gkey[boundary]) if len(boundary) else []:
            rows = boundary[gkey[boundary] == g]
            by_group_b[int(g)] = rows
        by_group_t: Dict[int, np.ndarray] = {}
        if len(top_ent):
            for g in np.unique(gkey[top_ent]):
                by_group_t[int(g)] = top_ent[gkey[top_ent] == g]

        groups = set(by_group_b) | set(by_group_t)
        for g in groups:
            stack = self._stacks.setdefault(g, [])
            b_rows = by_group_b.get(g, np.empty(0, np.int64))
            t_rows = by_group_t.get(g, np.empty(0, np.int64))
            # which boundary interval each top call falls into: index of the
            # first boundary row after it
            bucket = np.searchsorted(b_rows, t_rows)
            # per-bucket inclusive-time sums (tops between boundary events)
            sums = np.zeros(len(b_rows) + 1)
            if len(t_rows):
                np.add.at(sums, bucket, inc[t_rows])
            counts = np.zeros(len(b_rows) + 1, np.int64)
            if len(t_rows):
                np.add.at(counts, bucket, 1)

            def attribute(k):
                if counts[k]:
                    if stack:
                        stack[-1].child_inc += float(sums[k])
                    elif self._defer:
                        # belongs to whatever call is open in an earlier
                        # work unit — replayed by the parent at the seam
                        self._seams.setdefault(g, []).append(
                            ("a", float(sums[k])))

            attribute(0)
            for k, r in enumerate(b_rows):
                if is_enter[r]:
                    fr = _Frame(int(gcodes[r]), int(procs[r]), float(ts[r]))
                    fr.child_inc += pending_child.get(int(r), 0.0)
                    stack.append(fr)
                else:
                    if stack:
                        fr = stack.pop()
                        c_inc = float(ts[r]) - fr.start
                        c_exc = c_inc - fr.child_inc
                        completed.append((fr.name, fr.proc, fr.start,
                                          float(ts[r]), c_inc, c_exc))
                        if stack:
                            stack[-1].child_inc += c_inc
                        elif self._defer:
                            # the completed call's parent is open upstream
                            self._seams.setdefault(g, []).append(
                                ("a", c_inc))
                    elif self._defer:
                        # Leave whose Enter lives in an earlier unit: the
                        # parent pops the matching upstream carry frame
                        self._seams.setdefault(g, []).append(
                            ("l", float(ts[r]), int(procs[r])))
                    # else: leave with no open call anywhere upstream — the
                    # in-memory matcher leaves it unmatched too; ignore
                attribute(k + 1)
            if not stack:
                self._stacks.pop(g, None)
        return completed

    @staticmethod
    def _precomputed(ev: EventFrame
                     ) -> Optional[Tuple[np.ndarray, np.ndarray,
                                         np.ndarray, np.ndarray]]:
        """Chunk-localized structure attached by the reader (pack sidecar
        slices: partners/parents outside the chunk are -1, exactly the
        within-chunk result ``derive_structure`` would produce), or None.
        Readers must never attach these columns to a row-filtered chunk —
        ``mask_frames`` strips them before masking for the same reason."""
        if not (MATCH in ev and PARENT in ev and INC in ev and EXC in ev):
            return None
        return (np.asarray(ev.column(MATCH), np.int64),
                np.asarray(ev.column(PARENT), np.int64),
                np.asarray(ev.column(INC), np.float64),
                np.asarray(ev.column(EXC), np.float64))

    @staticmethod
    def _group_key_rows(ev: EventFrame) -> np.ndarray:
        """One stable (process, thread) integer key per row — must be
        identical across every chunk of a stream, since it indexes the
        carry stacks.  2³² headroom for the thread id: traces that keep raw
        OS tids (Linux pid_max ≤ 2²²) must not collide across processes."""
        proc = np.asarray(ev[PROC], np.int64)
        if THREAD in ev:
            thread = np.asarray(ev[THREAD], np.int64)
        else:
            thread = np.zeros_like(proc)
        return proc * (1 << 32) + thread


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def _validate_steps(steps: Sequence) -> None:
    from .query import SliceTimeStep
    for step in steps:
        if step.reads_derived():
            raise StreamingUnsupported(
                f"streaming plans cannot filter on derived columns "
                f"({step.describe()}): those values depend on the selected "
                f"frame.  Materialize first with .collect() or open with "
                f"streaming=False.")
        if isinstance(step, SliceTimeStep) and step.trim == "overlap":
            raise StreamingUnsupported(
                "slice_time(trim='overlap') extends the window through "
                "enter/leave matching, which streaming chunks cannot see "
                "ahead of time.  Use trim='within', or materialize with "
                ".collect() / streaming=False.")


def _steps_hints(steps: Sequence, base_procs=None,
                 base_bounds=None) -> registry.PlanHints:
    """Reader pushdown from the plan: the conjunction of process
    restrictions plus the intersection of within-trimmed windows."""
    from .query import SliceTimeStep
    bounds = base_bounds
    pset = frozenset(base_procs) if base_procs is not None else None
    window = None
    for step in steps:
        b, s = step.proc_hint()
        if b is not None:
            bounds = b if bounds is None else (max(bounds[0], b[0]),
                                               min(bounds[1], b[1]))
        if s is not None:
            pset = s if pset is None else (pset & s)
        if isinstance(step, SliceTimeStep) and step.trim == "within":
            window = ((step.start, step.end) if window is None else
                      (max(window[0], step.start), min(window[1], step.end)))
    return registry.PlanHints(procs=pset, proc_bounds=bounds,
                              time_window=window)


def mask_frames(frames: Iterator[EventFrame], steps: Sequence,
                label: Optional[str] = None) -> Iterator[EventFrame]:
    """The fused-mask-per-chunk pipeline: every frame the source yields is
    masked once with the AND of all step masks (mask fusion, per chunk)."""
    from .trace import Trace
    for frame in frames:
        if not steps:
            yield frame
            continue
        t = Trace(frame, label=label)
        mask = None
        for step in steps:
            m = step.mask(t)
            mask = m if mask is None else (mask & m)
        if mask.all():
            # keep the chunk as-is: precomputed structure columns (pack
            # sidecar slices) stay valid when no row is dropped
            yield frame
        else:
            # row selection invalidates any row-localized structure the
            # reader attached — strip before gathering so the stitcher
            # re-derives on the selected rows (identical to parse-time
            # pushdown in the text readers)
            yield frame.drop(*DERIVED_COLUMNS).mask(mask)


def _masked_chunks(handle: "StreamingTrace", steps: Sequence
                   ) -> Iterator[EventFrame]:
    hints = _steps_hints(steps)
    yield from mask_frames(handle._iter_frames(hints), steps, handle.label)


def stats_from_frames(frames: Iterator[EventFrame]) -> StreamStats:
    """One StreamStats pass over already-masked frames (exactly mergeable
    across partitions of the stream — see :meth:`StreamStats.merge`)."""
    from .constants import MPI_SEND, MSG_SIZE
    st = StreamStats()
    for frame in frames:
        n = len(frame)
        if n == 0:
            continue
        st.n_events += n
        ts = np.asarray(frame[TS], np.float64)
        st.ts_min = min(st.ts_min, float(ts.min()))
        st.ts_max = max(st.ts_max, float(ts.max()))
        st.proc_max = max(st.proc_max,
                          int(np.asarray(frame[PROC], np.int64).max()))
        if MSG_SIZE in frame:
            sends = frame.cat(NAME).mask_eq(MPI_SEND)
            if np.any(sends):
                sz = np.nan_to_num(
                    np.asarray(frame[MSG_SIZE], np.float64)[sends])
                st.n_sends += int(sends.sum())
                st.size_min = min(st.size_min, float(sz.min()))
                st.size_max = max(st.size_max, float(sz.max()))
    return st


def _stats_pass(handle: "StreamingTrace", steps: Sequence) -> StreamStats:
    return stats_from_frames(_masked_chunks(handle, steps))


def fold_frames(frames: Iterator[EventFrame], agg: StreamAgg,
                names: GlobalNames,
                stitcher: Optional[CallStitcher]) -> int:
    """Feed masked frames through the name interner / call stitcher into
    ``agg`` — the inner loop shared by the serial executor and every
    parallel worker.  Returns the max process id seen (or -1)."""
    proc_max = -1
    for frame in frames:
        if len(frame) == 0:
            continue
        gcodes = names.encode(frame.cat(NAME))
        calls = stitcher.push_chunk(frame, gcodes) if stitcher else None
        proc_max = max(proc_max, int(np.asarray(frame[PROC], np.int64).max()))
        agg.update(Chunk(frame, gcodes, calls, names))
    return proc_max


def execute_streaming(handle: "StreamingTrace", steps: Sequence,
                      spec: registry.OpSpec, args: tuple,
                      kwargs: dict, cache_flag: Optional[bool] = None
                      ) -> Any:
    """Run one registered op out of core over ``handle`` under ``steps``.

    When the handle asks for parallel execution (``executor="parallel"`` /
    ``processes=N``) and the op's aggregator declares a cross-worker merge,
    the plan fans out over work units through
    :func:`repro.core.executor.execute_parallel`; degradations back to the
    serial path always warn with the concrete reason (non-mergeable op,
    spawn-unsafe ``__main__``, nothing to fan out, unsplittable input).

    Live handles (:class:`LiveTrace`) with caching enabled take the
    **incremental** path: the running aggregation state is kept in the
    plan cache's live store, a re-query after the trace grew folds only
    the newly committed rows in, and the result is finalized from a copy
    — byte-identical to a full recompute over the same committed prefix,
    because both feed the identical global row sequence.
    """
    if spec.streaming is None:
        raise StreamingUnsupported(
            f"op {spec.name!r} has no combinable streaming form (it needs "
            f"the whole trace structure at once).  Materialize with "
            f".collect().{spec.name}(...) on the collected trace, or open "
            f"with streaming=False.")
    _validate_steps(steps)
    agg: StreamAgg = spec.streaming(*args, **kwargs)
    if (getattr(handle, "is_live", False) and handle.cache
            and cache_flag is not False and not agg.needs_stats
            and not handle.wants_parallel()):
        res = _execute_live_incremental(handle, steps, spec, args, kwargs,
                                        agg)
        if res is not _NO_INCREMENTAL:
            return res
    if handle.wants_parallel():
        from . import executor
        try:
            return executor.execute_parallel(handle, steps, spec, args,
                                             kwargs, agg)
        except executor.ParallelDegraded as d:
            import warnings
            warnings.warn(
                f"parallel streaming of op {spec.name!r} degraded to "
                f"serial: {d}", RuntimeWarning, stacklevel=3)
    stats = None
    if agg.needs_stats:
        # the handle caches its own no-extra-steps stats; reuse instead of
        # re-reading the stream when the plan adds nothing on top
        if tuple(steps) == tuple(handle._steps):
            stats = handle.stats()
        else:
            stats = _stats_pass(handle, steps)
    agg.begin(stats)
    names = GlobalNames()
    stitcher = CallStitcher() if agg.needs_calls else None
    proc_max = fold_frames(_masked_chunks(handle, steps), agg, names,
                           stitcher)
    open_calls = (stitcher.open_calls() if stitcher
                  else (np.empty(0, np.int64), np.empty(0, np.int64)))
    ctx = StreamContext(names, stats, open_calls, proc_max)
    return agg.result(ctx)


# ---------------------------------------------------------------------------
# live incremental execution (valid-up-to-row plan-cache semantics)
# ---------------------------------------------------------------------------

_NO_INCREMENTAL = object()  # sentinel: fall through to the full pass


class _LiveEntry:
    """Running aggregation state of one live plan: the persistent
    aggregator / name interner / call stitcher, how many rows of each
    path have been folded in, and a per-path fingerprint of the folded
    prefix (group count, last group's offset and CRC) that proves a later
    snapshot really *extends* it.  Guarded by its own lock — the service
    can poll the same plan from several lane threads."""

    __slots__ = ("agg", "names", "stitcher", "proc_max", "done", "marks",
                 "lock")

    def __init__(self, agg: StreamAgg):
        self.agg = agg
        self.names = GlobalNames()
        self.stitcher = CallStitcher() if agg.needs_calls else None
        self.proc_max = -1
        self.done: Dict[str, int] = {}    # path -> rows already folded
        self.marks: Dict[str, tuple] = {}  # path -> prefix fingerprint
        self.lock = threading.Lock()


def _prefix_mark(snap: dict, rows: int) -> tuple:
    """Fingerprint of the first ``rows`` rows of a committed-prefix
    snapshot: (groups, last group offset, last group CRC).  ``rows`` is
    always a group boundary (commits land whole groups)."""
    chunks = [c for c in snap["chunks"] if c["hi"] <= rows]
    if not chunks:
        return (0, 0, 0)
    last = chunks[-1]
    return (len(chunks), int(last["offset"]), int(last["crc"]))


def _extends(entry: _LiveEntry, handle: "LiveTrace") -> bool:
    """Does every path's current snapshot extend the prefix the entry has
    already folded?  False means the shard was rewritten/truncated under
    us — the partial is garbage and must be dropped."""
    for p, done in entry.done.items():
        if done == 0:
            continue
        snap = handle._snapshots.get(p)
        if snap is None or snap["rows"] < done:
            return False
        if entry.marks.get(p) != _prefix_mark(snap, done):
            return False
    return True


def _execute_live_incremental(handle: "LiveTrace", steps: Sequence,
                              spec: registry.OpSpec, args: tuple,
                              kwargs: dict, agg: StreamAgg) -> Any:
    """Incremental fold over a live handle's pinned snapshots.

    Correctness: the rows fed into the persistent aggregator across all
    calls form the identical global sequence a single full pass would
    feed (per path, rows [0, pinned) in order; paths in handle order), so
    first-seen name codes, stitcher carry state and every exactly
    -combinable partial agree bit-for-bit with a cold recompute over the
    same committed prefix.  The result is finalized on a deep copy so
    ``result()`` can never corrupt the stored partial.
    """
    from . import plancache
    from ..readers.pack import iter_chunks_pack
    key = plancache.live_plan_key(handle, steps, spec, args, kwargs)
    if key is None:
        return _NO_INCREMENTAL
    entry = plancache.live_lookup(key)
    if entry is not None and type(entry.agg) is not type(agg):
        entry = None  # key collision across agg classes: never reuse
    if entry is not None and not _extends(entry, handle):
        plancache.live_invalidate(key)
        entry = None
    fresh = entry is None
    if fresh:
        entry = _LiveEntry(agg)
        entry.agg.begin(None)
    hints = _steps_hints(steps)
    kw = {k: v for k, v in handle.reader_kwargs.items()
          if k not in ("live", "upto_rows", "report")}
    with entry.lock:
        try:
            for p in handle.paths:
                snap = handle._snapshots.get(p)
                pinned = snap["rows"] if snap else 0
                done = entry.done.get(p, 0)
                if pinned <= done:
                    continue
                frames = iter_chunks_pack(p, handle.chunk_rows, hints,
                                          row_range=(done, pinned),
                                          live=True, upto_rows=pinned, **kw)
                pm = fold_frames(mask_frames(frames, steps, handle.label),
                                 entry.agg, entry.names, entry.stitcher)
                entry.proc_max = max(entry.proc_max, pm)
                entry.done[p] = pinned
                entry.marks[p] = _prefix_mark(snap, pinned)
        except Exception:
            # a partially-updated entry is unusable; drop it.  A fresh
            # entry's failure is a genuine execution error (the full pass
            # would hit it too) — propagate.  A reused entry may fail on
            # state the full pass would not see (e.g. cross-path time
            # -order interleaving that only violates sortedness when fed
            # incrementally) — fall back to the full recompute.
            plancache.live_invalidate(key)
            if fresh:
                raise
            return _NO_INCREMENTAL
        plancache.live_store(key, entry)
        final_agg = copy.deepcopy(entry.agg)
        final_names = copy.deepcopy(entry.names)
        open_calls = (entry.stitcher.open_calls() if entry.stitcher
                      else (np.empty(0, np.int64), np.empty(0, np.int64)))
        proc_max = entry.proc_max
    ctx = StreamContext(final_names, None, open_calls, proc_max)
    return final_agg.result(ctx)


class Watermark:
    """Valid-up-to marker of a live read: the result covers exactly
    ``rows`` committed rows (per-path breakdown in ``per_path``) with
    events up to ``ts_max``.  ``finalized`` means every shard has sealed
    its footer — nothing more will ever arrive."""

    __slots__ = ("rows", "ts_max", "per_path", "finalized")

    def __init__(self, per_path: Dict[str, dict]):
        self.per_path = {p: dict(w) for p, w in per_path.items()}
        self.rows = sum(w["rows"] for w in self.per_path.values())
        ts = [w["ts_max"] for w in self.per_path.values()
              if w["ts_max"] is not None]
        self.ts_max = max(ts) if ts else None
        self.finalized = (all(w["finalized"]
                              for w in self.per_path.values())
                          if self.per_path else False)

    def as_dict(self) -> dict:
        return {"rows": self.rows, "ts_max": self.ts_max,
                "finalized": self.finalized,
                "per_path": {p: dict(w) for p, w in self.per_path.items()}}

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Watermark(rows={self.rows}, ts_max={self.ts_max}, "
                f"finalized={self.finalized})")


class LiveResult:
    """A live query's value plus the watermark it is valid up to."""

    __slots__ = ("value", "watermark")

    def __init__(self, value: Any, watermark: Watermark):
        self.value = value
        self.watermark = watermark

    def __iter__(self):  # tuple-style unpacking: value, watermark
        return iter((self.value, self.watermark))

    def __repr__(self) -> str:  # pragma: no cover
        return f"LiveResult({self.value!r}, {self.watermark!r})"


# ---------------------------------------------------------------------------
# chunked-reading plumbing
# ---------------------------------------------------------------------------

def iter_chunks_fallback(path: str, chunk_rows: int,
                         hints: Optional[registry.PlanHints],
                         reader: Callable[..., Any],
                         **reader_kwargs) -> Iterator[EventFrame]:
    """Correctness fallback for formats without a chunked reader: read the
    whole file, slice into ``chunk_rows`` windows.  No memory win — the
    streaming executor still works, but peak RSS matches the eager read."""
    ev = reader(path, **reader_kwargs).events
    for lo in range(0, len(ev), chunk_rows):
        yield ev.take(np.arange(lo, min(lo + chunk_rows, len(ev))))


class StreamingTrace:
    """A trace opened out of core: a handle over (possibly sharded) paths
    that is never fully materialized.

    ``query()`` starts a lazy plan whose terminal ops execute chunk by
    chunk; registered ops are also available directly
    (``st.flat_profile()``), exactly like on an in-memory Trace.  Member of
    a :class:`~repro.core.diff.TraceSet` works too — comparison ops stream
    each member.  ``materialize()`` is the escape hatch back to a fully
    loaded :class:`~repro.core.trace.Trace`.

    ``processes=N`` (or ``executor="parallel"``) fans terminal ops over a
    multi-core work-unit pool (:mod:`repro.core.executor`); ``cache=False``
    opts this handle out of the plan-result cache
    (:mod:`repro.core.plancache`).
    """

    def __init__(self, paths, format: str = "auto",
                 chunk_rows: int = DEFAULT_CHUNK_ROWS,
                 label: Optional[str] = None,
                 processes: Optional[int] = None, executor: str = "auto",
                 cache: bool = True, **reader_kwargs):
        if isinstance(paths, (str, bytes)) or hasattr(paths, "__fspath__"):
            paths = [paths]
        import os
        if executor not in ("auto", "serial", "parallel"):
            raise ValueError(f'executor must be "auto", "serial" or '
                             f'"parallel", got {executor!r}')
        self.paths = [os.fspath(p) for p in paths]
        self.format = format
        self.chunk_rows = int(chunk_rows)
        self.label = label or (self.paths[0] if self.paths else "stream")
        self.processes = processes
        self.executor = executor
        self.cache = cache
        self.reader_kwargs = reader_kwargs
        self._steps: tuple = ()
        self._stats0: Optional[StreamStats] = None  # no-selection stats
        self._pool = None  # SharedPool, possibly shared across a TraceSet
        self._units_cache: dict = {}  # work-unit plans per (paths, workers)
        from .errors import IngestReport
        self._ingest = IngestReport()  # filled by tolerant (on_error) reads

    def wants_parallel(self) -> bool:
        """True when terminal ops should try the multi-core executor."""
        if self.executor == "serial":
            return False
        if self.executor == "parallel":
            return True
        return self.processes is not None and self.processes > 1

    # -- plumbing ----------------------------------------------------------
    def _iter_frames(self, hints: Optional[registry.PlanHints] = None
                     ) -> Iterator[EventFrame]:
        """Chunks across all shard paths, with shard skipping (registered
        ``shard_procs`` hints) and per-chunk pushdown."""
        from ..readers.parallel import select_shards
        from .. import readers  # noqa: F401 — populate the registry
        procs = set(hints.procs) if hints and hints.procs is not None else None
        bounds = hints.proc_bounds if hints else None
        paths = select_shards(self.paths, self.format, procs=procs,
                              proc_bounds=bounds)
        kw = dict(self.reader_kwargs)
        if "on_error" in kw:
            # tolerant read: route per-record skip counts into this
            # handle's persistent report (readers reset their path entry
            # per pass, so multi-pass plans never double count)
            kw.setdefault("report", self._ingest)
        from .cancellation import check_cancelled
        for p in paths:
            spec = registry.resolve_reader(p, self.format)
            if spec.iter_chunks is not None:
                frames = spec.iter_chunks(p, self.chunk_rows, hints, **kw)
            else:
                frames = iter_chunks_fallback(p, self.chunk_rows, hints,
                                              spec.read, **kw)
            for frame in frames:
                # cooperative deadline point: a cancelled request (service
                # 504) frees its lane thread at the next chunk boundary
                check_cancelled()
                yield frame

    def iter_chunks(self) -> Iterator[EventFrame]:
        """Raw chunk frames (this handle's plan steps applied, masks
        fused per chunk)."""
        yield from _masked_chunks(self, self._steps)

    def ingest_report(self):
        """The :class:`~repro.core.errors.IngestReport` accumulated by
        tolerant (``on_error="skip"``) reads through this handle.  Counts
        reflect the most recent pass over each source path."""
        return self._ingest

    def with_steps(self, steps: Sequence) -> "StreamingTrace":
        """Shallow copy carrying plan ``steps`` — how a shared TraceSet
        plan binds its selection to each streaming member.  The clone
        shares this handle's worker pool (if any), so set-wide work keeps
        fanning into one pool."""
        clone = StreamingTrace(self.paths, format=self.format,
                               chunk_rows=self.chunk_rows, label=self.label,
                               processes=self.processes,
                               executor=self.executor, cache=self.cache,
                               **self.reader_kwargs)
        clone._steps = tuple(steps)
        clone._pool = self._pool
        clone._units_cache = self._units_cache  # same paths, same plans
        clone._ingest = self._ingest  # one report per logical handle
        return clone

    # -- materialization escape hatch --------------------------------------
    def load_raw(self, procs=None, proc_bounds=None):
        """Concatenate every chunk into one in-memory Trace *without*
        applying this handle's plan steps (the query engine applies them
        once — this is ``_StreamSource.load``)."""
        from .trace import Trace
        hints = registry.PlanHints(
            procs=frozenset(procs) if procs is not None else None,
            proc_bounds=proc_bounds)
        # chunked readers may attach chunk-localized structure columns
        # (pack sidecar); their indices are meaningless after concat
        frames = [f.drop(*DERIVED_COLUMNS)
                  for f in self._iter_frames(hints)]
        ev = concat(frames) if frames else EventFrame()
        return Trace(ev, label=self.label)

    def materialize(self):
        """Load everything into one in-memory Trace (applies this handle's
        plan steps, if any, via the normal fused-mask path)."""
        return self.query().collect()

    # -- conversion ---------------------------------------------------------
    def save_pack(self, path: str, chunk_rows: Optional[int] = None,
                  sidecar: bool = True) -> str:
        """Convert this handle's stream to the columnar pack format
        (:mod:`repro.readers.pack`) without ever materializing it.

        The handle's plan steps (if any) apply — what you save is what the
        handle selects.  ``sidecar=True`` additionally stores the structure
        sidecar via one memmap-backed pass over the *written* columns (the
        only whole-trace step; peak memory is the derived arrays, not the
        event text).  Returns ``path``.
        """
        from ..readers.pack import DEFAULT_PACK_CHUNK_ROWS, PackWriter
        with PackWriter(path, chunk_rows=chunk_rows or
                        DEFAULT_PACK_CHUNK_ROWS) as w:
            for frame in self.iter_chunks():
                w.append(frame.drop(*DERIVED_COLUMNS))
            return w.finish(sidecar=sidecar)

    # -- cheap whole-stream facts ------------------------------------------
    def stats(self) -> StreamStats:
        """One pass over the (selection-masked) stream: event count, time
        span, process count, message-size range.  Cached.  Fans over the
        worker pool when this handle runs parallel (StreamStats partials
        merge exactly)."""
        if self._stats0 is None:
            if self.wants_parallel():
                from . import executor
                try:
                    self._stats0 = executor.parallel_stats(self, self._steps)
                    return self._stats0
                except executor.ParallelDegraded:
                    pass  # stats have no mode choice to warn about
            self._stats0 = _stats_pass(self, self._steps)
        return self._stats0

    @property
    def num_processes(self) -> int:
        return self.stats().num_processes

    def __len__(self) -> int:
        return self.stats().n_events

    def __repr__(self) -> str:  # pragma: no cover
        return (f"StreamingTrace(label={self.label!r}, "
                f"{len(self.paths)} path(s), chunk_rows={self.chunk_rows}, "
                f"steps={len(self._steps)})")

    # -- query / terminal ops ----------------------------------------------
    def query(self):
        from .query import TraceQuery, _StreamSource
        return TraceQuery(_StreamSource(self), self._steps)

    def run(self, op_name: str, *args: Any, **kwargs: Any) -> Any:
        return self.query().run(op_name, *args, **kwargs)

    def __getattr__(self, name: str):
        return registry.terminal_op(name, self.run, "StreamingTrace")


class LiveTrace(StreamingTrace):
    """A still-growing trace opened live: plans execute over the
    **committed prefix** pinned at the last :meth:`refresh`, and results
    carry a :class:`Watermark` saying exactly how far they are valid.

    The handle snapshots each shard's committed prefix (group index +
    name table) when created and on every ``refresh()``; every read —
    serial, parallel (row-span work units), stats — is pinned to that
    snapshot, so a writer committing mid-query cannot leak rows into the
    result and eager == streaming == parallel digests hold on the prefix.
    With caching on (default), repeated terminal ops take the incremental
    path: only rows committed since the previous call are folded into the
    cached running aggregate (see :func:`execute_streaming`).

    A shard that does not exist yet, or has no committed groups, reads as
    empty — a live pipeline where data hasn't arrived is not an error.
    """

    is_live = True

    def __init__(self, paths, format: str = "auto",
                 chunk_rows: int = DEFAULT_CHUNK_ROWS,
                 label: Optional[str] = None,
                 processes: Optional[int] = None, executor: str = "auto",
                 cache: bool = True, **reader_kwargs):
        if format not in ("auto", "pack"):
            raise ValueError(
                f"live=True requires pack shards (the append/commit "
                f"protocol is a pack v2 feature), got format={format!r}")
        # workers inherit live semantics through reader_kwargs: a RowSpan
        # unit resolves the committed prefix, never a (missing) footer
        reader_kwargs = dict(reader_kwargs)
        reader_kwargs["live"] = True
        super().__init__(paths, format="pack", chunk_rows=chunk_rows,
                         label=label, processes=processes, executor=executor,
                         cache=cache, **reader_kwargs)
        self._snapshots: Dict[str, dict] = {}
        self.refresh()

    # -- snapshot control ----------------------------------------------------
    def refresh(self) -> Watermark:
        """Re-snapshot every shard's committed prefix and return the new
        :attr:`watermark`.  Cheap on unchanged shards (incremental cursor
        in the pack layer); invalidates this handle's cached stats and
        work-unit plans, which were pinned to the old snapshot."""
        from ..readers.pack import committed_prefix
        self._snapshots = {p: committed_prefix(p) for p in self.paths}
        self._stats0 = None
        self._units_cache.clear()
        return self.watermark

    @property
    def watermark(self) -> Watermark:
        """The pinned snapshot's validity marker (per-path breakdown
        included) — what every result of this handle is valid up to."""
        return Watermark({p: s["watermark"]
                          for p, s in self._snapshots.items()})

    # -- pinned plumbing -----------------------------------------------------
    def _iter_frames(self, hints: Optional[registry.PlanHints] = None
                     ) -> Iterator[EventFrame]:
        from ..readers.pack import iter_chunks_pack
        from .cancellation import check_cancelled
        kw = {k: v for k, v in self.reader_kwargs.items()
              if k not in ("live", "upto_rows")}
        for p in self.paths:
            snap = self._snapshots.get(p)
            pinned = snap["rows"] if snap else 0
            if pinned == 0:
                continue
            for frame in iter_chunks_pack(p, self.chunk_rows, hints,
                                          live=True, upto_rows=pinned,
                                          **kw):
                check_cancelled()
                yield frame

    def plan_units_for(self, path: str, n_units: int) -> List[Any]:
        """Authoritative work units for one shard, bounded by the pinned
        snapshot: RowSpans aligned to committed group boundaries.  The
        parallel planner uses these instead of the registry planner
        (whose footer read would fail on an unfinalized shard — and whose
        whole-path fallback would read past the watermark)."""
        snap = self._snapshots.get(path)
        chunks = snap["chunks"] if snap else []
        if not chunks:
            return []
        if n_units <= 1 or len(chunks) == 1:
            return [registry.RowSpan(path, 0, chunks[-1]["hi"])]
        groups = registry.even_groups(chunks, n_units)
        return [registry.RowSpan(path, g[0]["lo"], g[-1]["hi"])
                for g in groups]

    def with_steps(self, steps: Sequence) -> "LiveTrace":
        """Clone carrying plan ``steps`` that **shares this handle's
        pinned snapshots** (by reference): a set query over live members
        sees one consistent watermark, and a refresh on the parent moves
        every bound plan forward together."""
        clone = copy.copy(self)
        clone._steps = tuple(steps)
        clone._stats0 = None
        return clone

    # -- watermarked results -------------------------------------------------
    def run_with_watermark(self, op_name: str, *args: Any,
                           **kwargs: Any) -> LiveResult:
        """Run a terminal op and return ``LiveResult(value, watermark)``
        — the watermark captured from the pinned snapshot the execution
        actually covered."""
        wm = self.watermark
        return LiveResult(self.query().run(op_name, *args, **kwargs), wm)

    def __repr__(self) -> str:  # pragma: no cover
        wm = self.watermark
        return (f"LiveTrace(label={self.label!r}, {len(self.paths)} "
                f"path(s), rows={wm.rows}, finalized={wm.finalized})")
