"""Vectorized interval algebra used by comm/comp overlap and idle analyses."""

from __future__ import annotations

from typing import Tuple

import numpy as np


def merge_intervals(starts: np.ndarray, ends: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Union of possibly-overlapping intervals, as disjoint sorted intervals."""
    if len(starts) == 0:
        return starts[:0].astype(np.float64), ends[:0].astype(np.float64)
    order = np.argsort(starts, kind="stable")
    s = np.asarray(starts, np.float64)[order]
    e = np.asarray(ends, np.float64)[order]
    e = np.maximum.accumulate(e)
    # a new merged interval starts where s[i] > running max end of previous
    new = np.ones(len(s), dtype=bool)
    new[1:] = s[1:] > e[:-1]
    grp = np.cumsum(new) - 1
    out_s = s[new]
    out_e = np.zeros(len(out_s))
    np.maximum.at(out_e, grp, e)
    return out_s, out_e


def total_length(starts: np.ndarray, ends: np.ndarray) -> float:
    s, e = merge_intervals(starts, ends)
    return float(np.sum(e - s))


def intersect_length(a: Tuple[np.ndarray, np.ndarray], b: Tuple[np.ndarray, np.ndarray]) -> float:
    """|A ∩ B| = |A| + |B| − |A ∪ B| for merged interval sets."""
    la = float(np.sum(a[1] - a[0]))
    lb = float(np.sum(b[1] - b[0]))
    us, ue = merge_intervals(np.concatenate([a[0], b[0]]), np.concatenate([a[1], b[1]]))
    return la + lb - float(np.sum(ue - us))


def subtract_length(a: Tuple[np.ndarray, np.ndarray], b: Tuple[np.ndarray, np.ndarray]) -> float:
    """|A \\ B| for merged interval sets."""
    la = float(np.sum(a[1] - a[0]))
    return la - intersect_length(a, b)
