"""Pluggable op / reader registries — the extensibility backbone of the lazy
query layer (paper §IV-E, §VII: Pipit's claim is a *programmatic, extensible*
analysis API).

Two registries live here:

* **Op registry** — every §IV analysis operation registers itself with its
  declared prerequisites (``needs_structure``: enter/leave matching, parents,
  inc/exc; ``needs_messages``: send/recv matching).  The query engine
  (:mod:`repro.core.query`) reads these declarations to materialize each
  prerequisite *exactly once per plan* and users register custom analyses the
  same way the built-ins do::

      from repro.core.registry import register_op

      @register_op("send_count", needs_messages=True)
      def send_count(trace):
          ...

      trace.query().filter(f).send_count()   # chains like any built-in

* **Reader registry** — every trace format registers a reader plus an
  optional content sniffer and an optional per-shard process hint.
  ``Trace.open(path, format="auto")`` resolves the format here, and the
  parallel driver uses the shard hints to *skip shards before parsing* when
  the query plan restricts processes (predicate pushdown into readers).

This module is intentionally dependency-free (no imports from trace/query)
so both layers and all readers can import it without cycles.
"""

from __future__ import annotations

import os
import re
from dataclasses import dataclass, replace
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Set, Tuple)

__all__ = [
    "OpSpec", "register_op", "register_streaming", "get_op", "list_ops",
    "terminal_op",
    "register_backend", "get_backend", "op_backends", "list_backends",
    "ReaderSpec", "register_reader", "register_chunked", "register_units",
    "get_reader", "list_readers",
    "resolve_reader", "sniff_format", "rank_shard_procs", "PlanHints",
    "ByteSpan", "ProcSpan", "RowSpan", "even_edges", "even_groups",
]


@dataclass(frozen=True)
class PlanHints:
    """Pushdown hints a query plan hands to a chunked reader.

    Every field is advisory: a reader may drop rows/chunks that provably
    cannot satisfy the hints (cheaper than parsing then masking), or ignore
    any hint entirely — the streaming executor re-applies the full fused
    mask per chunk, so correctness never depends on reader cooperation.

    * ``procs`` — explicit set of process ids the plan restricts to;
    * ``proc_bounds`` — inclusive ``[lo, hi]`` bound on process ids;
    * ``time_window`` — inclusive ``[t0, t1]`` ns window such that every
      surviving row's own timestamp lies inside (only emitted for
      ``trim="within"`` windows — overlap windows extend past row
      timestamps and are never pushed down).
    """

    procs: Optional[frozenset] = None
    proc_bounds: Optional[Tuple[float, float]] = None
    time_window: Optional[Tuple[float, float]] = None

    def admits_proc(self, p: int) -> bool:
        if self.procs is not None and p not in self.procs:
            return False
        if self.proc_bounds is not None and not (
                self.proc_bounds[0] <= p <= self.proc_bounds[1]):
            return False
        return True


# ---------------------------------------------------------------------------
# parallel work units
# ---------------------------------------------------------------------------

def even_edges(lo: int, hi: int, n: int) -> List[int]:
    """n+1 monotone edges splitting [lo, hi) into ~equal integer spans —
    the one place the byte-range partition arithmetic lives (unit planners
    must not drift apart on span ownership)."""
    return [lo + (hi - lo) * i // n for i in range(n + 1)]


def even_groups(seq: Sequence, n: int) -> List[Tuple]:
    """Split ``seq`` into up to ``n`` contiguous non-empty tuples of ~equal
    length, preserving order — the shared group-partition arithmetic of the
    ProcSpan unit planners."""
    seq = list(seq)
    out = []
    for k in range(n):
        part = tuple(seq[len(seq) * k // n: len(seq) * (k + 1) // n])
        if part:
            out.append(part)
    return out


@dataclass(frozen=True)
class ByteSpan:
    """One byte range of a line/record-oriented trace file — a parallel work
    unit whose reader starts at the first record boundary at or after ``lo``
    and stops at the first boundary at or after ``hi``.  Spans planned over
    one file partition its records exactly: every record belongs to the span
    containing its first byte."""

    path: str
    lo: int
    hi: int


@dataclass(frozen=True)
class RowSpan:
    """One row range ``[lo, hi)`` of a random-access columnar trace file — a
    parallel work unit for formats whose footer index records exact row
    offsets (pipitpack).  Unlike :class:`ByteSpan` no boundary alignment is
    needed: the reader slices rows directly, so spans planned over one file
    partition its rows exactly by construction."""

    path: str
    lo: int
    hi: int


@dataclass(frozen=True)
class ProcSpan:
    """One process-subset work unit of a trace file: the rows of ``procs``
    only.  The executor *enforces* the subset with an explicit per-chunk
    mask (reader hints stay advisory) — spans over disjoint process sets
    therefore partition the rows exactly.  ``extra`` carries reader-specific
    keyword items (e.g. a pre-passed pid table) as a tuple of pairs."""

    path: str
    procs: Tuple[int, ...]
    extra: Tuple = ()


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OpSpec:
    """A registered analysis operation.

    ``scope`` declares the op's input shape: a ``"trace"`` op is
    ``fn(trace, *args, **kwargs)`` and terminates a single-trace
    :class:`~repro.core.query.TraceQuery`; a ``"set"`` op is
    ``fn(traces, *args, **kwargs)`` over a sequence of traces and terminates
    a :class:`~repro.core.diff.TraceSet` query.  Either way ``fn`` runs with
    the declared prerequisites already materialized (on every member trace
    for set-scoped ops).
    """

    name: str
    fn: Callable[..., Any]
    needs_structure: bool = False
    needs_messages: bool = False
    scope: str = "trace"
    #: factory building a streaming aggregator (see
    #: :mod:`repro.core.streaming`) for out-of-core execution, or None when
    #: the op has no combinable partial-aggregate form and must run on a
    #: fully materialized trace.
    streaming: Optional[Callable[..., Any]] = None
    #: True when the streaming aggregator also declares a cross-worker merge
    #: (``supports_parallel`` + ``merge_from`` on the aggregator class) —
    #: the parallel executor (:mod:`repro.core.executor`) fans such ops over
    #: a process pool; others degrade to serial streaming with a warning.
    parallel_safe: bool = False

    @property
    def backends(self) -> Tuple[str, ...]:
        """Names of this op's registered execution backends (sorted).

        Empty for ops without a ``backend=`` kwarg; ops that accept one
        always register at least ``"numpy"`` (the exact reference
        implementation) and usually ``"pallas"`` (the accelerator kernel,
        interpret-mode on CPU).  See :func:`register_backend`.
        """
        return tuple(list_backends(self.name))


_OP_REGISTRY: Dict[str, OpSpec] = {}

#: per-op backend tables: ``_BACKENDS[op][backend_name] -> callable``.  The
#: callable's contract is op-specific (documented on each op) — what the
#: registry guarantees is uniform *resolution*: every op with a ``backend=``
#: kwarg looks its argument up here and fails loudly listing the options.
_BACKENDS: Dict[str, Dict[str, Callable[..., Any]]] = {}


def op_backends(op_name: str) -> Dict[str, Callable[..., Any]]:
    """The live backend table of ``op_name`` (created on first use).

    Mutating the returned dict *is* the registration mechanism —
    :func:`register_backend` writes into it, and deleting a key
    unregisters the backend.  ``ops_summary.TIME_PROFILE_BACKENDS`` is an
    alias of ``op_backends("time_profile")`` for backwards compatibility.
    """
    return _BACKENDS.setdefault(op_name, {})


def register_backend(op_name: str, backend: str) -> Callable:
    """Decorator registering an execution backend for ``op_name``.

    Ops resolve their ``backend=`` kwarg through :func:`get_backend`;
    last registration wins, like the op registry itself::

        @register_backend("flat_profile", "my_accel")
        def _my_flat_profile(trace, *, metrics, groupby_column, per_process):
            ...

    The callable's signature is the op's own contract: trace-level ops
    take ``(trace, **op_kwargs)``; ``time_profile`` keeps its historical
    record-level contract ``fn(starts, ends, rate, name_codes, edges, nf)``
    (see docs/kernels.md).
    """

    def deco(fn: Callable) -> Callable:
        op_backends(op_name)[backend] = fn
        return fn

    return deco


def get_backend(op_name: str, backend: str) -> Callable[..., Any]:
    """Resolve ``backend`` for ``op_name`` or raise ValueError listing the
    registered names — the one lookup every ``backend=`` kwarg goes
    through (eager ops, streaming finalizers, and the serving layer)."""
    table = _BACKENDS.get(op_name)
    fn = table.get(backend) if table else None
    if fn is None:
        raise ValueError(
            f"unknown {op_name} backend {backend!r}; registered: "
            f"{sorted(table) if table else []}")
    return fn


def list_backends(op_name: str) -> List[str]:
    """Sorted backend names registered for ``op_name`` (empty when the op
    has no backend table)."""
    return sorted(_BACKENDS.get(op_name, ()))


def register_op(name: Optional[str] = None, *, needs_structure: bool = False,
                needs_messages: bool = False, scope: str = "trace") -> Callable:
    """Decorator registering an analysis op usable from ``TraceQuery``
    (``scope="trace"``, the default) or ``TraceSet`` (``scope="set"``).

    Re-registering a name overwrites the previous spec (last one wins), so
    user code can shadow a built-in analysis.
    """
    if scope not in ("trace", "set"):
        raise ValueError(f'scope must be "trace" or "set", got {scope!r}')

    def deco(fn: Callable) -> Callable:
        op_name = name or fn.__name__
        _OP_REGISTRY[op_name] = OpSpec(op_name, fn, needs_structure,
                                       needs_messages, scope)
        return fn

    return deco


def register_streaming(op_name: str) -> Callable:
    """Decorator declaring ``op_name``'s streaming (combinable) form.

    The decorated callable is an *aggregator factory*: called with the op's
    own ``(*args, **kwargs)`` it returns a streaming aggregator (see
    :class:`repro.core.streaming.StreamAgg`) whose mergeable partial results
    reproduce the in-memory op.  Ops without a registered factory raise a
    clear error under out-of-core execution instead of silently
    materializing the whole trace.

    Parallel safety is declared on the aggregator itself: a factory (class)
    carrying ``supports_parallel = True`` and a ``merge_from(other,
    code_map)`` method marks the op safe for multi-core execution, and the
    registry records that in :attr:`OpSpec.parallel_safe`.
    """

    def deco(factory: Callable) -> Callable:
        spec = _OP_REGISTRY.get(op_name)
        if spec is None:
            raise ValueError(
                f"cannot declare streaming form of unregistered op "
                f"{op_name!r}; register the op first")
        par = bool(getattr(factory, "supports_parallel", False)
                   and getattr(factory, "merge_from", None) is not None)
        _OP_REGISTRY[op_name] = replace(spec, streaming=factory,
                                        parallel_safe=par)
        return factory

    return deco


def get_op(name: str) -> Optional[OpSpec]:
    return _OP_REGISTRY.get(name)


def list_ops() -> List[str]:
    return sorted(_OP_REGISTRY)


def terminal_op(name: str, run: Callable[..., Any], owner: str) -> Callable:
    """Resolve ``name`` as a registered-op terminal bound to ``run`` — the
    shared ``__getattr__`` dispatch of TraceQuery, SetQuery and TraceSet.

    Raises AttributeError for dunder/private names and unknown ops so
    ``getattr``/``hasattr`` semantics stay intact on the owning object.
    """
    if name.startswith("_"):
        raise AttributeError(name)
    spec = get_op(name)
    if spec is None:
        raise AttributeError(
            f"{name!r} is neither a {owner} method nor a registered "
            f"analysis op (see repro.core.registry.list_ops())")

    def terminal(*args: Any, **kwargs: Any) -> Any:
        return run(name, *args, **kwargs)

    terminal.__name__ = name
    terminal.__qualname__ = f"{owner}.{name}"
    terminal.__doc__ = spec.fn.__doc__
    return terminal


# ---------------------------------------------------------------------------
# reader registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReaderSpec:
    """A registered trace-format reader.

    ``read(path, **kw)`` must return a Trace.  ``sniff(path, head)`` gets the
    path and the first few KB of file text and returns True when the content
    is this format.  ``shard_procs(path)`` optionally returns the set of
    process ids a shard file contains (or None when unknown) — the parallel
    driver uses it to skip shards a process-restricted plan cannot need.

    ``iter_chunks(path, chunk_rows, hints)`` optionally yields successive
    EventFrames of at most ``chunk_rows`` events each without ever holding
    the whole trace — the out-of-core streaming executor
    (:mod:`repro.core.streaming`) drives it.  ``hints`` is a
    :class:`PlanHints` carrying the plan's predicate/process/time-window
    pushdown; applying it is optional (the executor re-masks every chunk).
    Formats without a chunked reader fall back to a whole-file read sliced
    into chunks (correct, but with no memory win).

    ``plan_units(path, n_units)`` optionally splits one file into up to
    ``n_units`` independent parallel work units (:class:`ByteSpan` byte
    ranges for line-oriented formats, :class:`ProcSpan` process subsets
    otherwise) for the multi-core executor (:mod:`repro.core.executor`);
    returning None (or a single unit) means the file cannot be split and is
    processed whole.
    """

    name: str
    read: Callable[..., Any]
    extensions: Tuple[str, ...] = ()
    sniff: Optional[Callable[[str, str], bool]] = None
    shard_procs: Optional[Callable[[str], Optional[Set[int]]]] = None
    priority: int = 0  # higher sniffs first
    iter_chunks: Optional[Callable[..., Iterator[Any]]] = None
    plan_units: Optional[Callable[[str, int], Optional[List[Any]]]] = None


_READER_REGISTRY: Dict[str, ReaderSpec] = {}


def register_reader(name: str, *, extensions: Sequence[str] = (),
                    sniff: Optional[Callable[[str, str], bool]] = None,
                    shard_procs: Optional[Callable[[str], Optional[Set[int]]]] = None,
                    priority: int = 0,
                    iter_chunks: Optional[Callable[..., Iterator[Any]]] = None
                    ) -> Callable:
    """Decorator registering a reader callable under ``name``."""

    def deco(fn: Callable) -> Callable:
        _READER_REGISTRY[name] = ReaderSpec(
            name, fn, tuple(e.lower() for e in extensions), sniff,
            shard_procs, priority, iter_chunks)
        return fn

    return deco


def register_chunked(name: str) -> Callable:
    """Decorator attaching a chunked reader to the already-registered
    format ``name`` (readers usually register ``read`` first, then the
    chunked variant right below it)."""

    def deco(fn: Callable) -> Callable:
        spec = _READER_REGISTRY.get(name)
        if spec is None:
            raise ValueError(
                f"cannot attach chunked reader to unregistered format "
                f"{name!r}; register the reader first")
        _READER_REGISTRY[name] = replace(spec, iter_chunks=fn)
        return fn

    return deco


def register_units(name: str) -> Callable:
    """Decorator attaching a parallel unit planner (``plan_units(path,
    n_units)``) to the already-registered format ``name``."""

    def deco(fn: Callable) -> Callable:
        spec = _READER_REGISTRY.get(name)
        if spec is None:
            raise ValueError(
                f"cannot attach unit planner to unregistered format "
                f"{name!r}; register the reader first")
        _READER_REGISTRY[name] = replace(spec, plan_units=fn)
        return fn

    return deco


def get_reader(name: str) -> ReaderSpec:
    try:
        return _READER_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown trace format {name!r}; registered: {list_readers()}"
        ) from None


def list_readers() -> List[str]:
    return sorted(_READER_REGISTRY)


def _read_head(path: str, nbytes: int = 8192) -> str:
    try:
        with open(path, "r", errors="replace") as f:
            return f.read(nbytes)
    except (OSError, IsADirectoryError):
        return ""


def sniff_format(path) -> Optional[str]:
    """Guess the registered format of ``path`` from its name and content."""
    path = os.fspath(path)
    specs = sorted(_READER_REGISTRY.values(), key=lambda s: -s.priority)
    if os.path.isdir(path):
        for spec in specs:
            if spec.sniff and spec.sniff(path, ""):
                return spec.name
        return None
    low = path.lower()
    ext_hit = [s for s in specs if any(low.endswith(e) for e in s.extensions)]
    head = _read_head(path)
    # content sniff wins over extension: ".json" is shared by three formats
    for spec in specs:
        if spec.sniff and spec.sniff(path, head):
            return spec.name
    # the extension is only trusted for formats without a content sniffer: a
    # sniffer that just *rejected* this content knows better than the file
    # name, and handing the path to its reader anyway ends in a bare KeyError
    # deep inside the parse
    for spec in ext_hit:
        if spec.sniff is None:
            return spec.name
    return None


def _describe_readers() -> str:
    """One line per registered format: extensions and content sniffer."""
    parts = []
    for name in list_readers():
        spec = _READER_REGISTRY[name]
        ext = "/".join(spec.extensions) if spec.extensions else "any"
        sniffer = spec.sniff.__name__ if spec.sniff else "extension only"
        parts.append(f"{name} (extensions: {ext}; sniffer: {sniffer})")
    return ", ".join(parts)


_RANK_RE = re.compile(r"^rank[_\-.](\d+)\.")


def rank_shard_procs(path: str) -> Optional[Set[int]]:
    """Default shard hint: per-location shard files named ``rank_<p>.*``
    (the layout split_jsonl_by_process writes) contain exactly one process.
    Anchored to the whole stem — a file merely *containing* "rank" (e.g.
    ``lowrank_2.csv``) gets no hint and is never skipped."""
    m = _RANK_RE.match(os.path.basename(path))
    return {int(m.group(1))} if m else None


def resolve_reader(path, format: str = "auto") -> ReaderSpec:
    """Resolve ``format`` (or sniff when "auto") to a ReaderSpec.

    ``path`` may be anything os.fspath accepts (str, pathlib.Path, ...).
    """
    if format and format != "auto":
        return get_reader(format)
    name = sniff_format(path)
    if name is None:
        try:
            size = (None if os.path.isdir(path)
                    else os.path.getsize(os.fspath(path)))
        except OSError:
            size = None
        if size == 0:
            from .errors import TraceReadError
            raise TraceReadError(
                os.fspath(path),
                f"empty file (0 bytes) — cannot determine trace format. "
                f"Sniffers tried: {_describe_readers()}")
        raise ValueError(
            f"cannot determine trace format of {path!r}: no registered "
            f"sniffer recognized the content.  Registered formats: "
            f"{_describe_readers()}.  Pass format=<name> to force one.")
    return get_reader(name)
