"""NumPy-facing adapters over the Pallas reduction kernels, plus the
canonical record ordering every accelerator backend shares.

The op backends registered as ``backend="pallas"`` (flat_profile,
comm_matrix, message_histogram, load_imbalance, stragglers, time_profile)
all reduce a flat *record set* — completed calls or send instants — with
f32 kernel arithmetic.  f32 sums are order-dependent, and the eager,
streaming, parallel and pack paths naturally discover records in different
orders; the digest-identity contract (same backend → byte-identical result
on every path) therefore hinges on one rule:

    **every path sorts its records into the same canonical order and
    invokes the kernel exactly once, at finalize.**

:func:`canonical_order` is that order.  Its keys are path-independent:
timestamps, process ids, *alphabetical* name positions (never raw category
or interner codes, which differ between the eager code space and the
streaming first-seen code space), and the record's own value as the final
tiebreak.  See docs/kernels.md for the full precision contract.

This module is numpy-in / numpy-out — jax is imported lazily inside the
kernel calls so merely importing the core never pulls the accelerator
stack.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["canonical_order", "alpha_positions", "block_size", "seg_sum",
           "pair_sum", "hist_counts"]


def block_size(n: int) -> int:
    """Deterministic event-block size for the record kernels: 256 for
    small inputs, doubled until the sequential grid stays under ~512 steps
    (interpret mode walks the grid at Python speed, so step count — not
    record count — dominates CPU wall time; a real TPU bounds ``be`` by
    VMEM instead).  A pure function of N: every execution path holding the
    same record multiset picks the same partitioning, which keeps f32
    block sums — and therefore result digests — path-identical."""
    be = 256
    while n > be * 512 and be < 65536:
        be *= 2
    return be


def alpha_positions(names) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(sorted names, gather order, code→alphabetical-position map) for a
    code-aligned name table — the code-space-independent axis every pallas
    backend keys on.  ``arr[order]`` re-orders a code-indexed axis
    alphabetically; ``inv[code]`` is a code's alphabetical position."""
    names = np.asarray(list(names), dtype=object).astype(str)
    order = np.argsort(names, kind="stable")
    inv = np.empty(len(names), np.int64)
    inv[order] = np.arange(len(names))
    return names[order], order, inv


def canonical_order(start, end, proc, code, value) -> np.ndarray:
    """The shared sort of every accelerator backend: primary key ``start``,
    then ``end``, ``proc``, ``code`` (alphabetical name position — pass
    ``inv[raw_code]``), and ``value`` as the final tiebreak.  Records equal
    on *all* keys are interchangeable, so any two paths that hold the same
    record multiset feed the kernel bit-identical blocks."""
    return np.lexsort((np.asarray(value, np.float64),
                       np.asarray(code, np.int64),
                       np.asarray(proc, np.int64),
                       np.asarray(end, np.float64),
                       np.asarray(start, np.float64)))


def seg_sum(code: np.ndarray, values: np.ndarray, n_seg: int) -> np.ndarray:
    """Per-segment column sums on the accelerator: code [N] (<0 ignored),
    values [N] or [N, K] → float64 [n_seg] / [n_seg, K] (f32 kernel
    arithmetic, widened on the way out)."""
    import jax.numpy as jnp

    from ..kernels.ops import segment_sum_matrix
    values = np.asarray(values, np.float64)
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    if n_seg <= 0 or values.shape[1] == 0:
        out = np.zeros((max(n_seg, 0), values.shape[1]))
        return out[:, 0] if squeeze else out
    out = np.asarray(segment_sum_matrix(
        jnp.asarray(np.asarray(code, np.int64)),
        jnp.asarray(values, jnp.float32), n_seg=int(n_seg),
        be=block_size(len(values))), np.float64)
    return out[:, 0] if squeeze else out


def pair_sum(a: np.ndarray, b: np.ndarray, w: np.ndarray, n_a: int,
             n_b: int) -> np.ndarray:
    """Weighted 2-D scatter-add on the accelerator: a, b [N] (<0 ignored),
    w [N] → float64 [n_a, n_b]."""
    if n_a <= 0 or n_b <= 0:
        return np.zeros((max(n_a, 0), max(n_b, 0)))
    import jax.numpy as jnp

    from ..kernels.ops import pair_sum_matrix
    return np.asarray(pair_sum_matrix(
        jnp.asarray(np.asarray(a, np.int64)),
        jnp.asarray(np.asarray(b, np.int64)),
        jnp.asarray(np.asarray(w, np.float64), jnp.float32),
        n_a=int(n_a), n_b=int(n_b), be=block_size(len(np.asarray(a)))),
        np.float64)


def hist_counts(idx: np.ndarray, n_bins: int) -> np.ndarray:
    """Exact histogram counts on the accelerator: host-computed bin indices
    go in centered at ``idx + 0.5`` (f32-exact below 2²³), the in-kernel
    floor recovers them exactly, so the int64 counts match
    ``np.histogram`` bit for bit."""
    if n_bins <= 0:
        return np.zeros(max(n_bins, 0), np.int64)
    import jax.numpy as jnp

    from ..kernels.ops import histogram_counts
    coords = np.asarray(idx, np.float64) + 0.5
    out = np.asarray(histogram_counts(
        jnp.asarray(coords, jnp.float32), n_bins=int(n_bins),
        be=block_size(len(coords))))
    return np.rint(out).astype(np.int64)
