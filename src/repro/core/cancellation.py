"""Cooperative cancellation for long-running executions.

The trace-query service enforces per-request deadlines: when a deadline
expires, the event loop answers 504 immediately, but the plan is still
running on a scheduler lane thread.  Python threads cannot be killed —
the only way to free the lane is for the work itself to notice.  This
module provides that signal:

* :class:`CancelToken` — a thread-safe flag the deadline watcher sets;
* :func:`cancel_scope` — binds a token to the *current thread* for the
  duration of an execution;
* :func:`check_cancelled` — the cheap check long loops call at natural
  yield points (the streaming engine calls it at every chunk boundary),
  raising :class:`ExecutionCancelled` when the bound token fired.

Only the thread that entered the scope sees the token, so concurrent
executions on other lane threads are unaffected.  Work fanned out to a
multiprocess executor does not observe tokens (processes finish their
current work unit); the serial and streaming paths — where a runaway
full scan actually pins a lane — cancel within one chunk.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Iterator, Optional

__all__ = ["ExecutionCancelled", "CancelToken", "cancel_scope",
           "current_token", "check_cancelled"]


class ExecutionCancelled(RuntimeError):
    """Raised by :func:`check_cancelled` when the current scope's token
    was cancelled (e.g. the request's deadline expired)."""


class CancelToken:
    """A thread-safe one-way cancellation flag."""

    def __init__(self, reason: str = "cancelled"):
        self._flag = threading.Event()
        self.reason = reason

    def cancel(self, reason: Optional[str] = None) -> None:
        if reason is not None:
            self.reason = reason
        self._flag.set()

    @property
    def cancelled(self) -> bool:
        return self._flag.is_set()

    def check(self) -> None:
        if self._flag.is_set():
            raise ExecutionCancelled(self.reason)

    def __repr__(self) -> str:  # pragma: no cover
        state = "cancelled" if self.cancelled else "live"
        return f"CancelToken({state}, reason={self.reason!r})"


_tls = threading.local()


def current_token() -> Optional[CancelToken]:
    """The token bound to this thread by :func:`cancel_scope`, or None."""
    return getattr(_tls, "token", None)


def check_cancelled() -> None:
    """Raise :class:`ExecutionCancelled` if this thread's bound token was
    cancelled; no-op (and near-free) when no scope is active."""
    tok = getattr(_tls, "token", None)
    if tok is not None and tok.cancelled:
        raise ExecutionCancelled(tok.reason)


@contextmanager
def cancel_scope(token: CancelToken) -> Iterator[CancelToken]:
    """Bind ``token`` to the current thread for the duration of the block
    (scopes nest; the previous binding is restored on exit)."""
    prev = getattr(_tls, "token", None)
    _tls.token = token
    try:
        yield token
    finally:
        _tls.token = prev
