"""Visualization support (paper §V), rendered with matplotlib (Agg).

The paper's Bokeh views map 1:1 onto these functions; each returns the
matplotlib Axes (and saves to ``save`` when given) so examples/benchmarks can
emit the same figures as the paper: timeline (Figs. 8-10), time profile
(Fig. 2), comm matrix (Fig. 3), comm by process (Fig. 6), message histogram
(Fig. 4), multirun stacked bars (Figs. 12-13).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

from .constants import ENTER, ET, MATCH, MPI_RECV, MPI_SEND, NAME, PROC, TS
from .frame import EventFrame

_CMAP = plt.get_cmap("tab20")


def _color(i: int):
    return _CMAP(i % 20)


def plot_timeline(trace, x_start: Optional[float] = None, x_end: Optional[float] = None,
                  show_messages: bool = True, show_critical_path: bool = False,
                  max_functions: int = 19, ax=None, save: Optional[str] = None):
    """Events-over-time view: bars per call offset by depth, arrows per message."""
    trace._ensure_structure()
    ev = trace.events
    ts = np.asarray(ev[TS], np.float64)
    match = np.asarray(ev.column(MATCH), np.int64)
    depth = np.asarray(ev.column("_depth"), np.int64)
    procs = np.asarray(ev[PROC], np.int64)
    names = ev.codes(NAME)
    cats = ev.cat(NAME).categories

    if x_start is None:
        x_start = float(ts.min())
    if x_end is None:
        x_end = float(ts.max())

    if ax is None:
        _, ax = plt.subplots(figsize=(12, 0.6 * (trace.num_processes + 2) + 1))

    is_enter = ev.cat(ET).mask_eq(ENTER)
    sel = np.nonzero(is_enter & (match >= 0))[0]
    s, e = ts[sel], ts[match[sel]]
    vis = (e >= x_start) & (s <= x_end)
    sel, s, e = sel[vis], s[vis], e[vis]

    # color by function, rank functions by total time for a stable legend
    tot = np.zeros(len(cats))
    np.add.at(tot, names[sel], e - s)
    rank = np.argsort(-tot, kind="stable")
    color_of = np.full(len(cats), max_functions, np.int64)
    color_of[rank[:max_functions]] = np.arange(min(max_functions, len(rank)))

    lane = procs[sel].astype(np.float64) + 0.08 * np.minimum(depth[sel], 8)
    for i, row in enumerate(sel):
        ax.barh(lane[i], e[i] - s[i], left=s[i], height=0.35,
                color=_color(color_of[names[row]]), edgecolor="none")
    if show_messages and trace._msg_match is None:
        trace._ensure_messages()
    if show_messages and trace._msg_match is not None:
        mm = trace._msg_match
        name_cat = ev.cat(NAME)
        sends = np.nonzero(name_cat.mask_eq(MPI_SEND) & (mm >= 0))[0]
        for srow in sends[:2000]:
            rrow = mm[srow]
            if ts[srow] > x_end or ts[rrow] < x_start:
                continue
            ax.annotate("", xy=(ts[rrow], procs[rrow]), xytext=(ts[srow], procs[srow]),
                        arrowprops=dict(arrowstyle="->", color="black", lw=0.6, alpha=0.6))
    if show_critical_path:
        paths = trace.critical_path_analysis()
        if paths and len(paths[0]):
            p = paths[0]
            ax.plot(np.asarray(p[TS], np.float64), np.asarray(p[PROC], np.float64),
                    "r-o", lw=1.6, ms=3, label="critical path")
            ax.legend(loc="upper right")
    handles = [plt.Rectangle((0, 0), 1, 1, color=_color(i)) for i in
               range(min(max_functions, len(rank)))]
    labels = [str(cats[rank[i]]) for i in range(min(max_functions, len(rank)))]
    if handles:
        ax.legend(handles, labels, loc="center left", bbox_to_anchor=(1.0, 0.5),
                  fontsize=7)
    ax.set_xlim(x_start, x_end)
    ax.set_xlabel("time (ns)")
    ax.set_ylabel("process")
    ax.set_yticks(range(trace.num_processes))
    ax.invert_yaxis()
    if save:
        ax.figure.savefig(save, bbox_inches="tight", dpi=110)
        plt.close(ax.figure)
    return ax


def plot_time_profile(trace, num_bins: int = 32, ax=None, save: Optional[str] = None):
    prof = trace.time_profile(num_bins=num_bins)
    cols = [c for c in prof.columns if c not in ("bin_start", "bin_end")]
    if ax is None:
        _, ax = plt.subplots(figsize=(10, 4))
    x = np.asarray(prof["bin_start"], np.float64)
    width = np.asarray(prof["bin_end"], np.float64) - x
    bottom = np.zeros(len(x))
    for i, c in enumerate(cols[:19]):
        v = np.asarray(prof[c], np.float64)
        ax.bar(x, v, width=width, bottom=bottom, align="edge", label=c,
               color=_color(i), edgecolor="none")
        bottom += v
    ax.set_xlabel("time (ns)")
    ax.set_ylabel("total time per bin")
    ax.legend(fontsize=7, loc="center left", bbox_to_anchor=(1.0, 0.5))
    if save:
        ax.figure.savefig(save, bbox_inches="tight", dpi=110)
        plt.close(ax.figure)
    return ax


def plot_comm_matrix(trace, output: str = "size", log_scale: bool = False,
                     ax=None, save: Optional[str] = None):
    mat = trace.comm_matrix(output=output)
    if ax is None:
        _, ax = plt.subplots(figsize=(5.5, 5))
    from matplotlib.colors import LogNorm
    norm = LogNorm(vmin=max(mat[mat > 0].min(), 1e-9), vmax=mat.max()) \
        if log_scale and (mat > 0).any() else None
    im = ax.imshow(mat, cmap="viridis", norm=norm)
    ax.figure.colorbar(im, ax=ax, label=f"{output} sent")
    ax.set_xlabel("receiver")
    ax.set_ylabel("sender")
    if save:
        ax.figure.savefig(save, bbox_inches="tight", dpi=110)
        plt.close(ax.figure)
    return ax


def plot_comm_by_process(trace, output: str = "size", ax=None,
                         save: Optional[str] = None):
    t = trace.comm_by_process(output=output)
    if ax is None:
        _, ax = plt.subplots(figsize=(9, 3.5))
    procs = np.asarray(t[PROC], np.int64)
    ax.bar(procs - 0.2, np.asarray(t["sent"]), width=0.4, label="sent")
    ax.bar(procs + 0.2, np.asarray(t["received"]), width=0.4, label="received")
    ax.set_xlabel("process")
    ax.set_ylabel(output)
    ax.legend()
    if save:
        ax.figure.savefig(save, bbox_inches="tight", dpi=110)
        plt.close(ax.figure)
    return ax


def plot_message_histogram(trace, bins: int = 10, ax=None, save: Optional[str] = None):
    counts, edges = trace.message_histogram(bins=bins)
    if ax is None:
        _, ax = plt.subplots(figsize=(7, 3.5))
    ax.bar(edges[:-1], counts, width=np.diff(edges), align="edge", edgecolor="white")
    ax.set_xlabel("message size (bytes)")
    ax.set_ylabel("count")
    if save:
        ax.figure.savefig(save, bbox_inches="tight", dpi=110)
        plt.close(ax.figure)
    return ax


def plot_multirun(table: EventFrame, label_column: str = "Run", ax=None,
                  save: Optional[str] = None):
    """Stacked bars across runs (paper Figs. 12-13)."""
    cols = [c for c in table.columns if c != label_column]
    labels = [str(x) for x in table[label_column]]
    if ax is None:
        _, ax = plt.subplots(figsize=(8, 4))
    x = np.arange(len(labels))
    bottom = np.zeros(len(labels))
    for i, c in enumerate(cols[:19]):
        v = np.asarray(table[c], np.float64)
        ax.bar(x, v, bottom=bottom, label=c, color=_color(i))
        bottom += v
    ax.set_xticks(x, labels, rotation=20, ha="right", fontsize=8)
    ax.legend(fontsize=7, loc="center left", bbox_to_anchor=(1.0, 0.5))
    if save:
        ax.figure.savefig(save, bbox_inches="tight", dpi=110)
        plt.close(ax.figure)
    return ax
