"""Communication analysis operations (paper §IV-C)."""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from . import accel
from .constants import (DEFAULT_COMM_PREFIXES, ENTER, ET, INC, LEAVE, MPI_RECV,
                        MPI_SEND, MSG_SIZE, NAME, PARTNER, PROC, TS)
from .frame import EventFrame
from .intervals import merge_intervals
from .registry import (get_backend, register_backend, register_op,
                       register_streaming)
from .streaming import StreamAgg, StreamingUnsupported, grow_to

__all__ = [
    "comm_matrix", "message_histogram", "comm_by_process", "comm_over_time",
    "comm_comp_breakdown", "comm_name_mask",
]


def _sends(trace) -> EventFrame:
    ev = trace.events
    if PARTNER not in ev:
        return EventFrame({TS: np.asarray([], np.int64)})
    return ev.mask(ev.cat(NAME).mask_eq(MPI_SEND))


@register_op("comm_matrix", needs_messages=True)
def comm_matrix(trace, output: str = "size",
                backend: str = "numpy") -> np.ndarray:
    """Process-to-process communication matrix (§IV-C, Fig. 3).

    Aggregates every send instant by (sender, receiver).

    Args:
        output: ``"size"`` (default) sums message bytes; ``"count"`` (any
            other value) counts messages.
        backend: ``"numpy"`` (default, exact) or ``"pallas"`` (pair_sum
            one-hot matmul kernel, f32 rounding; see docs/kernels.md).

    Returns:
        ``(nprocs, nprocs)`` float array; ``M[i, j]`` is the bytes (or
        number of messages) process i sent to process j.  All zeros when
        the trace records no messages.
    """
    return get_backend("comm_matrix", backend)(trace, output=output)


@register_backend("comm_matrix", "numpy")
def _comm_matrix_numpy(trace, *, output: str = "size") -> np.ndarray:
    """The exact reference: one scatter-add over the send instants."""
    s = _sends(trace)
    n = trace.num_processes
    mat = np.zeros((n, n))
    if len(s) == 0:
        return mat
    src = np.asarray(s[PROC], np.int64)
    dst = np.asarray(s[PARTNER], np.int64)
    w = np.asarray(s[MSG_SIZE], np.float64) if output == "size" else np.ones(len(s))
    np.add.at(mat, (src, dst), np.nan_to_num(w))
    return mat


def _wrap_partners(src, dst, n: int, op: str):
    """Negative partner ids wrap like numpy fancy indexing (``-1`` is the
    last process); out-of-range ids raise the same IndexError the
    ``np.add.at`` reference raises instead of silently dropping."""
    if len(dst) and (int(src.max()) >= n or int(dst.max()) >= n
                     or int(src.min()) < 0 or int(dst.min()) < -n):
        raise IndexError(
            f"{op}: message endpoints outside the selected trace's "
            f"0..{n - 1} process range (same selection fails on "
            f"backend='numpy' too)")
    return np.where(dst < 0, dst + n, dst)


@register_backend("comm_matrix", "pallas")
def _comm_matrix_pallas(trace, *, output: str = "size") -> np.ndarray:
    """Accelerator comm matrix: canonical-ordered send records through the
    pair_sum one-hot-matmul kernel (f32 rounding; counts exact)."""
    s = _sends(trace)
    n = trace.num_processes
    if len(s) == 0 or n == 0:
        return np.zeros((n, n))
    src = np.asarray(s[PROC], np.int64)
    dst = np.asarray(s[PARTNER], np.int64)
    w = np.nan_to_num(np.asarray(s[MSG_SIZE], np.float64)) \
        if output == "size" else np.ones(len(s))
    dst = _wrap_partners(src, dst, n, "comm_matrix backend='pallas'")
    ts = np.asarray(s[TS], np.float64)
    o = accel.canonical_order(ts, ts, src, dst, w)
    return accel.pair_sum(src[o], dst[o], w[o], n, n)


@register_op("message_histogram")
def message_histogram(trace, bins: int = 10,
                      backend: str = "numpy") -> Tuple[np.ndarray, np.ndarray]:
    """Distribution of message sizes (§IV-C, Fig. 4).

    Args:
        bins: number of equal-width size bins over [min, max] bytes.
        backend: ``"numpy"`` (default) or ``"pallas"`` (one-hot matmul
            binning kernel).  Bin indices are computed host-side with exact
            ``np.histogram`` semantics, so both backends return *identical*
            counts (see docs/kernels.md).

    Returns:
        ``(counts, edges)`` à la ``np.histogram``: ``counts`` has ``bins``
        message counts, ``edges`` has ``bins + 1`` byte boundaries.
    """
    return get_backend("message_histogram", backend)(trace, bins=bins)


@register_backend("message_histogram", "numpy")
def _message_histogram_numpy(trace, *, bins: int = 10
                             ) -> Tuple[np.ndarray, np.ndarray]:
    s = _sends(trace)
    if len(s) == 0:
        return np.zeros(bins, np.int64), np.linspace(0, 1, bins + 1)
    sizes = np.nan_to_num(np.asarray(s[MSG_SIZE], np.float64))
    return np.histogram(sizes, bins=bins)


def _hist_indices(sizes: np.ndarray, edges: np.ndarray,
                  bins: int) -> np.ndarray:
    """Exact ``np.histogram`` bin assignment: half-open bins with the last
    bin closed — ``searchsorted(side="right") - 1`` over the edge array,
    clipped so the top edge lands in the final bin."""
    return np.clip(np.searchsorted(edges, sizes, side="right") - 1,
                   0, bins - 1)


@register_backend("message_histogram", "pallas")
def _message_histogram_pallas(trace, *, bins: int = 10
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """Accelerator size histogram: exact host-side bin indices go through
    the hist_bin one-hot counting kernel — counts match numpy bit for
    bit (integer counts are exact in f32 below 2²⁴ per bin)."""
    s = _sends(trace)
    if len(s) == 0:
        return np.zeros(bins, np.int64), np.linspace(0, 1, bins + 1)
    sizes = np.nan_to_num(np.asarray(s[MSG_SIZE], np.float64))
    edges = np.histogram_bin_edges(sizes, bins=bins)
    return accel.hist_counts(_hist_indices(sizes, edges, bins), bins), edges


@register_op("comm_by_process")
def comm_by_process(trace, output: str = "size") -> EventFrame:
    """Total communication volume per process (§IV-C).

    Args:
        output: ``"size"`` (default) sums bytes; anything else counts
            messages.

    Returns:
        EventFrame with one row per process: ``Process``, ``sent``,
        ``received``, and ``total`` (sent + received), in bytes or message
        counts.
    """
    s = _sends(trace)
    n = trace.num_processes
    sent = np.zeros(n)
    recv = np.zeros(n)
    if len(s):
        src = np.asarray(s[PROC], np.int64)
        dst = np.asarray(s[PARTNER], np.int64)
        w = np.asarray(s[MSG_SIZE], np.float64) if output == "size" else np.ones(len(s))
        w = np.nan_to_num(w)
        np.add.at(sent, src, w)
        np.add.at(recv, dst, w)
    return EventFrame({PROC: np.arange(n, dtype=np.int32), "sent": sent,
                       "received": recv, "total": sent + recv})


@register_op("comm_over_time")
def comm_over_time(trace, num_bins: int = 32, output: str = "size"
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Message traffic over time (§IV-C): sends binned by timestamp.

    Args:
        num_bins: equal-width time bins over the whole trace span.
        output: ``"size"`` (default) sums bytes per bin; anything else
            counts messages per bin.

    Returns:
        ``(values, edges)``: ``values`` has ``num_bins`` totals, ``edges``
        has ``num_bins + 1`` bin boundaries in ns.
    """
    s = _sends(trace)
    ev = trace.events
    ts_all = np.asarray(ev[TS], np.float64)
    t0 = float(ts_all.min()) if len(ev) else 0.0
    t1 = float(ts_all.max()) if len(ev) else 1.0
    edges = np.linspace(t0, max(t1, t0 + 1), num_bins + 1)
    if len(s) == 0:
        return np.zeros(num_bins), edges
    w = np.asarray(s[MSG_SIZE], np.float64) if output == "size" else np.ones(len(s))
    vals, _ = np.histogram(np.asarray(s[TS], np.float64), bins=edges,
                           weights=np.nan_to_num(w))
    return vals, edges


# ---------------------------------------------------------------------------
# streaming (out-of-core) forms — message aggregates are naturally
# combinable: every send instant carries its (sender, receiver, bytes)
# inline, so per-chunk partial sums merge exactly
# ---------------------------------------------------------------------------

def _chunk_sends(chunk):
    """(src, dst, size) arrays of the send instants in a chunk."""
    ev = chunk.events
    if PARTNER not in ev:
        return None
    sel = ev.cat(NAME).mask_eq(MPI_SEND)
    if not np.any(sel):
        return None
    return (np.asarray(ev[PROC], np.int64)[sel],
            np.asarray(ev[PARTNER], np.int64)[sel],
            np.nan_to_num(np.asarray(ev[MSG_SIZE], np.float64)[sel]),
            np.asarray(ev[TS], np.float64)[sel])


def _check_partner_range(extent: int, n: int, op: str) -> None:
    """The in-memory ops size their output by the selected trace's process
    count and raise on partner ids beyond it (np.add.at IndexError);
    silently truncating here would turn that loud failure into wrong
    zeros — e.g. restrict_processes([0]) then comm_matrix()."""
    if extent > n:
        raise IndexError(
            f"streaming {op}: message partner ids reach process "
            f"{extent - 1} but the selected stream only contains processes "
            f"0..{n - 1}; widen the process restriction to cover message "
            f"partners (the in-memory path fails on this selection too)")


@register_streaming("comm_matrix")
class _CommMatrixAgg(StreamAgg):
    """Combinable comm matrix: per-chunk (sender, receiver) partial sums.
    ``backend="pallas"`` buffers the send records and runs the pair_sum
    kernel once at finalize, exactly like the eager pallas backend."""

    supports_parallel = True

    def __init__(self, output: str = "size", backend: str = "numpy"):
        get_backend("comm_matrix", backend)
        if backend not in ("numpy", "pallas"):
            raise StreamingUnsupported(
                f"streaming comm_matrix supports backends ('numpy', "
                f"'pallas'); {backend!r} is trace-level — materialize with "
                f".collect() to use it")
        self.backend = backend
        self.output = output
        self._recs: list = []
        self._mat = np.zeros((0, 0))
        self._neg = np.zeros(0)  # sends with partner -1, keyed by sender
        self._extent = 0

    def update(self, chunk) -> None:
        s = _chunk_sends(chunk)
        if s is None:
            return
        src, dst, size, ts = s
        w = size if self.output == "size" else np.ones(len(src))
        if self.backend != "numpy":
            pos = dst >= 0
            self._extent = max(self._extent, int(src.max()) + 1,
                               int(dst[pos].max()) + 1 if pos.any() else 0)
            self._recs.append((src, dst, w, ts))
            return
        neg = dst < 0
        if np.any(neg):
            # the in-memory op's np.add.at wraps dst=-1 into the LAST
            # column of its n×n matrix; n is only known at finalize, so
            # park these per sender and place them then
            n = int(src[neg].max()) + 1
            self._neg = grow_to(self._neg, (n,))
            np.add.at(self._neg, src[neg], w[neg])
            src, dst, w = src[~neg], dst[~neg], w[~neg]
        if not len(src):
            return
        n = int(max(src.max(), dst.max())) + 1
        self._extent = max(self._extent, n)
        self._mat = grow_to(self._mat, (n, n))
        np.add.at(self._mat, (src, dst), w)

    def merge_from(self, other, code_map) -> None:
        # everything is keyed by global process ids — no name remap at all
        self._extent = max(self._extent, other._extent)
        if self.backend != "numpy":
            self._recs.extend(other._recs)
            return
        self._mat = grow_to(self._mat, other._mat.shape)
        a, b = other._mat.shape
        self._mat[:a, :b] += other._mat
        self._neg = grow_to(self._neg, other._neg.shape)
        self._neg[: len(other._neg)] += other._neg

    def result(self, ctx) -> np.ndarray:
        n = ctx.num_processes
        _check_partner_range(self._extent, n, "comm_matrix")
        if self.backend != "numpy":
            if not self._recs or n == 0:
                return np.zeros((n, n))
            src = np.concatenate([r[0] for r in self._recs])
            dst = np.concatenate([r[1] for r in self._recs])
            w = np.concatenate([r[2] for r in self._recs])
            ts = np.concatenate([r[3] for r in self._recs])
            dst = _wrap_partners(src, dst, n, "streaming comm_matrix")
            o = accel.canonical_order(ts, ts, src, dst, w)
            return accel.pair_sum(src[o], dst[o], w[o], n, n)
        out = np.zeros((max(n, 0), max(n, 0)))
        sub = self._mat[:n, :n]
        out[: sub.shape[0], : sub.shape[1]] = sub
        if n and np.any(self._neg):
            out[: min(n, len(self._neg)), n - 1] += self._neg[:n]
        return out


@register_streaming("comm_by_process")
class _CommByProcessAgg(StreamAgg):
    """Combinable per-process communication volume."""

    supports_parallel = True

    def __init__(self, output: str = "size"):
        self.output = output
        self._sent = np.zeros(0)
        self._recv = np.zeros(0)
        self._neg = 0.0  # receives credited to partner -1 (wraps to last)
        self._extent = 0

    def update(self, chunk) -> None:
        s = _chunk_sends(chunk)
        if s is None:
            return
        src, dst, size, _ts = s
        w = size if self.output == "size" else np.ones(len(src))
        n = int(src.max()) + 1
        self._sent = grow_to(self._sent, (n,))
        np.add.at(self._sent, src, w)
        neg = dst < 0
        if np.any(neg):
            # in-memory np.add.at(recv, -1, w) wraps to the last process
            self._neg += float(w[neg].sum())
            dst, w = dst[~neg], w[~neg]
        if not len(dst):
            return
        n = int(dst.max()) + 1
        self._extent = max(self._extent, n)
        self._recv = grow_to(self._recv, (n,))
        np.add.at(self._recv, dst, w)

    def merge_from(self, other, code_map) -> None:
        self._sent = grow_to(self._sent, other._sent.shape)
        self._sent[: len(other._sent)] += other._sent
        self._recv = grow_to(self._recv, other._recv.shape)
        self._recv[: len(other._recv)] += other._recv
        self._neg += other._neg
        self._extent = max(self._extent, other._extent)

    def result(self, ctx) -> EventFrame:
        n = ctx.num_processes
        _check_partner_range(self._extent, n, "comm_by_process")
        sent = np.zeros(max(n, 0))
        recv = np.zeros(max(n, 0))
        sent[: min(n, len(self._sent))] = self._sent[:n]
        recv[: min(n, len(self._recv))] = self._recv[:n]
        if n:
            recv[n - 1] += self._neg
        return EventFrame({PROC: np.arange(n, dtype=np.int32), "sent": sent,
                           "received": recv, "total": sent + recv})


@register_streaming("message_histogram")
class _MessageHistogramAgg(StreamAgg):
    """Combinable size histogram: a stats pre-pass fixes the [min, max]
    byte range (the same edges ``np.histogram`` derives), then per-chunk
    counts over those edges merge exactly."""

    needs_stats = True
    supports_parallel = True

    def __init__(self, bins: int = 10, backend: str = "numpy"):
        get_backend("message_histogram", backend)
        if backend not in ("numpy", "pallas"):
            raise StreamingUnsupported(
                f"streaming message_histogram supports backends ('numpy', "
                f"'pallas'); {backend!r} is trace-level — materialize with "
                f".collect() to use it")
        self.backend = backend
        self.bins = bins
        self._sizes: list = []
        self._counts = np.zeros(bins, np.int64)
        self._edges: Optional[np.ndarray] = None

    def begin(self, stats) -> None:
        if stats.n_sends == 0:
            return
        self._edges = np.histogram_bin_edges(
            np.asarray([stats.size_min, stats.size_max]), bins=self.bins,
            range=(stats.size_min, stats.size_max))

    def update(self, chunk) -> None:
        if self._edges is None:
            return
        s = _chunk_sends(chunk)
        if s is None:
            return
        _src, _dst, size, _ts = s
        if self.backend != "numpy":
            self._sizes.append(size)
            return
        c, _ = np.histogram(size, bins=self._edges)
        self._counts += c

    def merge_from(self, other, code_map) -> None:
        # edges were fixed by the shared stats pre-pass; counts just add
        if self.backend != "numpy":
            self._sizes.extend(other._sizes)
            return
        self._counts += other._counts

    def result(self, ctx) -> Tuple[np.ndarray, np.ndarray]:
        if self._edges is None:
            return np.zeros(self.bins, np.int64), np.linspace(0, 1,
                                                              self.bins + 1)
        if self.backend != "numpy":
            sizes = (np.concatenate(self._sizes) if self._sizes
                     else np.zeros(0))
            return accel.hist_counts(
                _hist_indices(sizes, self._edges, self.bins),
                self.bins), self._edges
        return self._counts, self._edges


@register_streaming("comm_over_time")
class _CommOverTimeAgg(StreamAgg):
    """Combinable traffic-over-time: bin edges come from the stats pre-pass
    (whole-stream time span), per-chunk weighted histograms merge exactly
    for integer byte counts."""

    needs_stats = True
    supports_parallel = True

    def __init__(self, num_bins: int = 32, output: str = "size"):
        self.num_bins = num_bins
        self.output = output
        self._vals = np.zeros(num_bins)
        self._edges: Optional[np.ndarray] = None

    def begin(self, stats) -> None:
        t0 = stats.ts_min if stats.n_events else 0.0
        t1 = stats.ts_max if stats.n_events else 1.0
        self._edges = np.linspace(t0, max(t1, t0 + 1), self.num_bins + 1)

    def update(self, chunk) -> None:
        s = _chunk_sends(chunk)
        if s is None:
            return
        _src, _dst, size, ts = s
        w = size if self.output == "size" else np.ones(len(ts))
        v, _ = np.histogram(ts, bins=self._edges, weights=w)
        self._vals += v

    def merge_from(self, other, code_map) -> None:
        self._vals += other._vals

    def result(self, ctx) -> Tuple[np.ndarray, np.ndarray]:
        return self._vals, self._edges


def comm_name_mask(events: EventFrame,
                   prefixes: Sequence[str] = DEFAULT_COMM_PREFIXES) -> np.ndarray:
    """Boolean mask over the *category table* rows mapped to events: True where
    the event's function name looks like communication."""
    cat = events.cat(NAME)
    subs = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute", "nccl", "send", "recv")
    is_comm_cat = np.zeros(len(cat.categories), dtype=bool)
    for i, c in enumerate(cat.categories):
        cs = str(c)
        low = cs.lower()
        is_comm_cat[i] = cs.startswith(tuple(prefixes)) or any(s in low for s in subs)
    return is_comm_cat[cat.codes]


@register_op("comm_comp_breakdown", needs_structure=True)
def comm_comp_breakdown(trace, comm_matcher: Optional[Callable[[str], bool]] = None
                        ) -> EventFrame:
    """Per-process split of wall time into non-overlapped computation,
    computation overlapped with communication, non-overlapped communication,
    and other/idle (§IV-C, Fig. 13).

    Communication and computation can only overlap across threads/streams of
    the same process (e.g. a compute stream and a NCCL stream); interval
    algebra over the merged per-class interval sets yields the split.

    Args:
        comm_matcher: ``fn(name) -> bool`` deciding which functions count
            as communication; default matches MPI/NCCL/collective name
            patterns (see :func:`comm_name_mask`).

    Returns:
        EventFrame with one row per process: ``Process``, ``comp_only``,
        ``overlap``, ``comm_only``, ``other`` (unaccounted/idle), and
        ``span`` (the process's wall-clock extent) — all in ns, with
        ``comp_only + overlap + comm_only + other == span``.
    """
    ev = trace.events
    n = len(ev)
    procs = np.asarray(ev[PROC], np.int64)
    ts = np.asarray(ev[TS], np.float64)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    is_enter = ev.cat(ET).mask_eq(ENTER)

    if comm_matcher is None:
        comm_mask = comm_name_mask(ev)
    else:
        cat = ev.cat(NAME)
        per_cat = np.asarray([bool(comm_matcher(str(c))) for c in cat.categories])
        comm_mask = per_cat[cat.codes]

    # leaf calls: matched enters with no child enter inside → use exclusive
    # spans approximated by call spans of *leaf* calls to avoid double count.
    parent = np.asarray(ev.column("_parent"), np.int64)
    has_child = np.zeros(n, dtype=bool)
    pe = parent[(parent >= 0) & is_enter]
    has_child[pe[pe >= 0]] = True

    sel = np.nonzero(is_enter & (match >= 0))[0]
    leaf = sel[~has_child[sel]]
    comm_leaf = leaf[comm_mask[leaf]]
    comp_leaf = leaf[~comm_mask[leaf]]
    # a call that *contains* only comm children is itself comm plumbing; treat
    # non-leaf comm calls' spans as comm too (covers MPI_Wait around Isend).
    comm_any = sel[comm_mask[sel]]

    nprocs = trace.num_processes
    cols = {k: np.zeros(nprocs) for k in
            ("comp_only", "overlap", "comm_only", "other", "span")}
    for p in range(nprocs):
        def spans(rows):
            rows = rows[procs[rows] == p]
            return merge_intervals(ts[rows], ts[match[rows]])
        comm_iv = spans(comm_any)
        comp_iv = spans(comp_leaf)
        p_rows = np.nonzero(procs == p)[0]
        if len(p_rows) == 0:
            continue
        span = float(ts[p_rows].max() - ts[p_rows].min())
        lcomm = float(np.sum(comm_iv[1] - comm_iv[0]))
        lcomp = float(np.sum(comp_iv[1] - comp_iv[0]))
        us, ue = merge_intervals(np.concatenate([comm_iv[0], comp_iv[0]]),
                                 np.concatenate([comm_iv[1], comp_iv[1]]))
        lunion = float(np.sum(ue - us))
        ov = lcomm + lcomp - lunion
        cols["overlap"][p] = ov
        cols["comm_only"][p] = lcomm - ov
        cols["comp_only"][p] = lcomp - ov
        cols["other"][p] = max(span - lunion, 0.0)
        cols["span"][p] = span
    out = EventFrame({PROC: np.arange(nprocs, dtype=np.int32)})
    for k, v in cols.items():
        out[k] = v
    return out
