"""Communication analysis operations (paper §IV-C)."""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from .constants import (DEFAULT_COMM_PREFIXES, ENTER, ET, INC, LEAVE, MPI_RECV,
                        MPI_SEND, MSG_SIZE, NAME, PARTNER, PROC, THREAD, TS)
from .frame import EventFrame
from .intervals import merge_intervals
from .registry import register_op

__all__ = [
    "comm_matrix", "message_histogram", "comm_by_process", "comm_over_time",
    "comm_comp_breakdown", "comm_name_mask",
]


def _sends(trace) -> EventFrame:
    ev = trace.events
    if PARTNER not in ev:
        return EventFrame({TS: np.asarray([], np.int64)})
    return ev.mask(ev.cat(NAME).mask_eq(MPI_SEND))


@register_op("comm_matrix", needs_messages=True)
def comm_matrix(trace, output: str = "size") -> np.ndarray:
    """Process-to-process communication matrix (§IV-C, Fig. 3).

    Aggregates every send instant by (sender, receiver).

    Args:
        output: ``"size"`` (default) sums message bytes; ``"count"`` (any
            other value) counts messages.

    Returns:
        ``(nprocs, nprocs)`` float array; ``M[i, j]`` is the bytes (or
        number of messages) process i sent to process j.  All zeros when
        the trace records no messages.
    """
    s = _sends(trace)
    n = trace.num_processes
    mat = np.zeros((n, n))
    if len(s) == 0:
        return mat
    src = np.asarray(s[PROC], np.int64)
    dst = np.asarray(s[PARTNER], np.int64)
    w = np.asarray(s[MSG_SIZE], np.float64) if output == "size" else np.ones(len(s))
    np.add.at(mat, (src, dst), np.nan_to_num(w))
    return mat


@register_op("message_histogram")
def message_histogram(trace, bins: int = 10) -> Tuple[np.ndarray, np.ndarray]:
    """Distribution of message sizes (§IV-C, Fig. 4).

    Args:
        bins: number of equal-width size bins over [min, max] bytes.

    Returns:
        ``(counts, edges)`` à la ``np.histogram``: ``counts`` has ``bins``
        message counts, ``edges`` has ``bins + 1`` byte boundaries.
    """
    s = _sends(trace)
    if len(s) == 0:
        return np.zeros(bins, np.int64), np.linspace(0, 1, bins + 1)
    sizes = np.nan_to_num(np.asarray(s[MSG_SIZE], np.float64))
    return np.histogram(sizes, bins=bins)


@register_op("comm_by_process")
def comm_by_process(trace, output: str = "size") -> EventFrame:
    """Total communication volume per process (§IV-C).

    Args:
        output: ``"size"`` (default) sums bytes; anything else counts
            messages.

    Returns:
        EventFrame with one row per process: ``Process``, ``sent``,
        ``received``, and ``total`` (sent + received), in bytes or message
        counts.
    """
    s = _sends(trace)
    n = trace.num_processes
    sent = np.zeros(n)
    recv = np.zeros(n)
    if len(s):
        src = np.asarray(s[PROC], np.int64)
        dst = np.asarray(s[PARTNER], np.int64)
        w = np.asarray(s[MSG_SIZE], np.float64) if output == "size" else np.ones(len(s))
        w = np.nan_to_num(w)
        np.add.at(sent, src, w)
        np.add.at(recv, dst, w)
    return EventFrame({PROC: np.arange(n, dtype=np.int32), "sent": sent,
                       "received": recv, "total": sent + recv})


@register_op("comm_over_time")
def comm_over_time(trace, num_bins: int = 32, output: str = "size"
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Message traffic over time (§IV-C): sends binned by timestamp.

    Args:
        num_bins: equal-width time bins over the whole trace span.
        output: ``"size"`` (default) sums bytes per bin; anything else
            counts messages per bin.

    Returns:
        ``(values, edges)``: ``values`` has ``num_bins`` totals, ``edges``
        has ``num_bins + 1`` bin boundaries in ns.
    """
    s = _sends(trace)
    ev = trace.events
    ts_all = np.asarray(ev[TS], np.float64)
    t0 = float(ts_all.min()) if len(ev) else 0.0
    t1 = float(ts_all.max()) if len(ev) else 1.0
    edges = np.linspace(t0, max(t1, t0 + 1), num_bins + 1)
    if len(s) == 0:
        return np.zeros(num_bins), edges
    w = np.asarray(s[MSG_SIZE], np.float64) if output == "size" else np.ones(len(s))
    vals, _ = np.histogram(np.asarray(s[TS], np.float64), bins=edges,
                           weights=np.nan_to_num(w))
    return vals, edges


def comm_name_mask(events: EventFrame,
                   prefixes: Sequence[str] = DEFAULT_COMM_PREFIXES) -> np.ndarray:
    """Boolean mask over the *category table* rows mapped to events: True where
    the event's function name looks like communication."""
    cat = events.cat(NAME)
    subs = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute", "nccl", "send", "recv")
    is_comm_cat = np.zeros(len(cat.categories), dtype=bool)
    for i, c in enumerate(cat.categories):
        cs = str(c)
        low = cs.lower()
        is_comm_cat[i] = cs.startswith(tuple(prefixes)) or any(s in low for s in subs)
    return is_comm_cat[cat.codes]


@register_op("comm_comp_breakdown", needs_structure=True)
def comm_comp_breakdown(trace, comm_matcher: Optional[Callable[[str], bool]] = None
                        ) -> EventFrame:
    """Per-process split of wall time into non-overlapped computation,
    computation overlapped with communication, non-overlapped communication,
    and other/idle (§IV-C, Fig. 13).

    Communication and computation can only overlap across threads/streams of
    the same process (e.g. a compute stream and a NCCL stream); interval
    algebra over the merged per-class interval sets yields the split.

    Args:
        comm_matcher: ``fn(name) -> bool`` deciding which functions count
            as communication; default matches MPI/NCCL/collective name
            patterns (see :func:`comm_name_mask`).

    Returns:
        EventFrame with one row per process: ``Process``, ``comp_only``,
        ``overlap``, ``comm_only``, ``other`` (unaccounted/idle), and
        ``span`` (the process's wall-clock extent) — all in ns, with
        ``comp_only + overlap + comm_only + other == span``.
    """
    ev = trace.events
    n = len(ev)
    procs = np.asarray(ev[PROC], np.int64)
    ts = np.asarray(ev[TS], np.float64)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    is_enter = ev.cat(ET).mask_eq(ENTER)

    if comm_matcher is None:
        comm_mask = comm_name_mask(ev)
    else:
        cat = ev.cat(NAME)
        per_cat = np.asarray([bool(comm_matcher(str(c))) for c in cat.categories])
        comm_mask = per_cat[cat.codes]

    # leaf calls: matched enters with no child enter inside → use exclusive
    # spans approximated by call spans of *leaf* calls to avoid double count.
    parent = np.asarray(ev.column("_parent"), np.int64)
    has_child = np.zeros(n, dtype=bool)
    pe = parent[(parent >= 0) & is_enter]
    has_child[pe[pe >= 0]] = True

    sel = np.nonzero(is_enter & (match >= 0))[0]
    leaf = sel[~has_child[sel]]
    comm_leaf = leaf[comm_mask[leaf]]
    comp_leaf = leaf[~comm_mask[leaf]]
    # a call that *contains* only comm children is itself comm plumbing; treat
    # non-leaf comm calls' spans as comm too (covers MPI_Wait around Isend).
    comm_any = sel[comm_mask[sel]]

    nprocs = trace.num_processes
    cols = {k: np.zeros(nprocs) for k in
            ("comp_only", "overlap", "comm_only", "other", "span")}
    for p in range(nprocs):
        def spans(rows):
            rows = rows[procs[rows] == p]
            return merge_intervals(ts[rows], ts[match[rows]])
        comm_iv = spans(comm_any)
        comp_iv = spans(comp_leaf)
        p_rows = np.nonzero(procs == p)[0]
        if len(p_rows) == 0:
            continue
        span = float(ts[p_rows].max() - ts[p_rows].min())
        lcomm = float(np.sum(comm_iv[1] - comm_iv[0]))
        lcomp = float(np.sum(comp_iv[1] - comp_iv[0]))
        us, ue = merge_intervals(np.concatenate([comm_iv[0], comp_iv[0]]),
                                 np.concatenate([comm_iv[1], comp_iv[1]]))
        lunion = float(np.sum(ue - us))
        ov = lcomm + lcomp - lunion
        cols["overlap"][p] = ov
        cols["comm_only"][p] = lcomm - ov
        cols["comp_only"][p] = lcomp - ov
        cols["other"][p] = max(span - lunion, 0.0)
        cols["span"][p] = span
    out = EventFrame({PROC: np.arange(nprocs, dtype=np.int32)})
    for k, v in cols.items():
        out[k] = v
    return out
