"""Shared error types and the ingest report for fault-tolerant reads.

Every reader in this repo accepts an ``on_error`` policy:

* ``"strict"`` (default) — any malformed input raises :class:`TraceReadError`
  with the file path and the most precise locus available (line number for
  text formats, byte offset for binary ones).  Nothing is silently dropped.
* ``"skip"`` (text/document readers) — malformed records are dropped and
  counted; the surviving rows are exactly the rows a strict read of an
  undamaged copy would produce for them, so eager == streaming == parallel
  digest identity holds over the survivors.
* ``"salvage"`` / ``"skip_chunk"`` (pack) — see :mod:`repro.readers.pack`.

Counts land in an :class:`IngestReport` exposed as ``Trace.ingest_report()``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

__all__ = ["TraceReadError", "IngestReport", "check_on_error",
           "require_nonempty"]

#: cap on per-path stored error samples (counts are always exact)
MAX_ERROR_SAMPLES = 8


class TraceReadError(ValueError):
    """A trace file could not be read (or contains malformed records under
    the strict policy).  Carries the path and an optional locus so the
    message always says *where*."""

    def __init__(self, path: str, reason: str,
                 locus: Optional[str] = None):
        self.path = str(path)
        self.reason = reason
        self.locus = locus
        where = f"{self.path}:{locus}" if locus else self.path
        super().__init__(f"{where}: {reason}")


def check_on_error(value: str, allowed: Tuple[str, ...]) -> str:
    if value not in allowed:
        raise ValueError(f"on_error must be one of {allowed}, got {value!r}")
    return value


def require_nonempty(path: str, size: int, minimum: int = 1,
                     what: str = "trace") -> None:
    """Raise the canonical empty/too-short error for ``path``."""
    if size == 0:
        raise TraceReadError(path, f"empty file (0 bytes) — not a readable "
                                   f"{what}")
    if size < minimum:
        raise TraceReadError(path, f"too-short file ({size} bytes, a "
                                   f"{what} needs at least {minimum})")


class IngestReport:
    """Exact per-path accounting of what a tolerant read kept and dropped.

    One entry per source path with ``rows`` (surviving rows), ``skipped``
    (individually identified records dropped), ``bytes_lost`` (unparseable
    tail bytes for document formats, where a per-record count does not
    exist), and up to ``MAX_ERROR_SAMPLES`` error strings.  Re-reading the
    same path (streaming plans scan a source more than once) resets that
    path's entry first, so counts reflect one pass, never a sum of passes.
    """

    def __init__(self) -> None:
        self._paths: Dict[str, dict] = {}

    # -- recording ---------------------------------------------------------
    def begin(self, path: str) -> None:
        self._paths[str(path)] = {"rows": 0, "skipped": 0, "bytes_lost": 0,
                                  "errors": []}

    def _entry(self, path: str) -> dict:
        e = self._paths.get(str(path))
        if e is None:
            self.begin(path)
            e = self._paths[str(path)]
        return e

    def add_rows(self, path: str, n: int) -> None:
        self._entry(path)["rows"] += int(n)

    def skip(self, path: str, n: int, locus: str, reason: str) -> None:
        e = self._entry(path)
        e["skipped"] += int(n)
        if len(e["errors"]) < MAX_ERROR_SAMPLES:
            e["errors"].append(f"{locus}: {reason}")

    def lose_bytes(self, path: str, n: int, locus: str, reason: str) -> None:
        e = self._entry(path)
        e["bytes_lost"] += int(n)
        if len(e["errors"]) < MAX_ERROR_SAMPLES:
            e["errors"].append(f"{locus}: {reason}")

    # -- reading -----------------------------------------------------------
    @property
    def clean(self) -> bool:
        return all(e["skipped"] == 0 and e["bytes_lost"] == 0
                   for e in self._paths.values())

    def total_skipped(self) -> int:
        return sum(e["skipped"] for e in self._paths.values())

    def errors(self) -> List[str]:
        return [f"{p} {m}" for p, e in sorted(self._paths.items())
                for m in e["errors"]]

    def as_dict(self) -> dict:
        return {"clean": self.clean,
                "paths": {p: dict(e, errors=list(e["errors"]))
                          for p, e in self._paths.items()}}

    def __repr__(self) -> str:
        n = len(self._paths)
        return (f"IngestReport(paths={n}, skipped={self.total_skipped()}, "
                f"clean={self.clean})")
