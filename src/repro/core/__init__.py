"""repro.core — the paper's contribution: Pipit, a programmatic trace-analysis
library on a columnar event model (here NumPy-backed; pandas is unavailable).

Public surface mirrors the paper's API: ``Trace`` with ``from_*`` readers and
the §IV operations as methods, ``Filter`` DSL, ``EventFrame`` as the
DataFrame-equivalent escape hatch for custom wrangling.
"""

from .cct import CCT, CCTNode
from .constants import (ENTER, ET, EXC, INC, INSTANT, LEAVE, MPI_RECV,
                        MPI_SEND, MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD,
                        TS)
from .detectors import (DetectorSpec, Findings, get_detector, is_comm_name,
                        list_detectors, register_detector)
from .diff import SetQuery, TraceSet
from .filters import Filter, time_window_filter
from .frame import Categorical, EventFrame, concat
from .frame import optimize_dtypes
from .ops_patterns import mass, matrix_profile
from .query import TraceQuery, scan
from .registry import (PlanHints, get_backend, list_backends, list_ops,
                       list_readers, op_backends, register_backend,
                       register_chunked, register_op, register_reader,
                       register_streaming)
from .liveset import Coverage, LiveTraceSet
from .streaming import (LiveResult, LiveTrace, StreamingTrace,
                        StreamingUnsupported, Watermark)
from .trace import Trace

__all__ = [
    "Trace", "TraceQuery", "scan", "TraceSet", "SetQuery", "EventFrame",
    "Categorical", "concat", "optimize_dtypes", "Filter",
    "time_window_filter", "CCT",
    "CCTNode", "mass", "matrix_profile", "register_op", "register_reader",
    "register_streaming", "register_chunked", "PlanHints",
    "register_backend", "get_backend", "op_backends", "list_backends",
    "register_detector", "get_detector", "list_detectors", "DetectorSpec",
    "Findings", "is_comm_name",
    "StreamingTrace", "StreamingUnsupported",
    "LiveTrace", "LiveResult", "Watermark", "LiveTraceSet", "Coverage",
    "list_ops", "list_readers",
    "TS", "ET", "NAME", "PROC", "THREAD", "ENTER", "LEAVE", "INSTANT",
    "INC", "EXC", "MSG_SIZE", "PARTNER", "TAG", "MPI_SEND", "MPI_RECV",
]
