"""Pattern detection via matrix profiles (paper §IV-D, Fig. 8).

STUMPY is unavailable offline, so we implement the underlying algorithms
directly: MASS (Mueen's Algorithm for Similarity Search — z-normalized
sliding-window distances via FFT convolution) and the STOMP-style matrix
profile built from it.  The public entry point, :func:`detect_pattern`,
reproduces the paper's workflow: given a ``start_event`` hint it finds the
repeating occurrences of that event, validates the period with the matrix
profile of the binned-activity series, and returns one EventFrame per
detected occurrence (time-windowed slices of the trace).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .constants import ENTER, ET, EXC, NAME, PROC, TS
from .frame import EventFrame
from .registry import register_op

__all__ = ["mass", "matrix_profile", "activity_series", "detect_pattern"]


def _sliding_stats(series: np.ndarray, m: int) -> Tuple[np.ndarray, np.ndarray]:
    """Mean/std of every length-m window, via cumulative sums (O(n))."""
    s = np.concatenate([[0.0], np.cumsum(series)])
    s2 = np.concatenate([[0.0], np.cumsum(series.astype(np.float64) ** 2)])
    n = len(series) - m + 1
    mu = (s[m:] - s[:-m]) / m
    var = (s2[m:] - s2[:-m]) / m - mu**2
    return mu, np.sqrt(np.maximum(var, 1e-20))


def mass(query: np.ndarray, series: np.ndarray) -> np.ndarray:
    """Z-normalized Euclidean distance of ``query`` to every window of
    ``series`` (MASS): one FFT-based correlation + O(1) per-window algebra."""
    q = np.asarray(query, np.float64)
    t = np.asarray(series, np.float64)
    m, n = len(q), len(t)
    if n < m:
        return np.asarray([])
    qm, qs = q.mean(), max(q.std(), 1e-10)
    qz = (q - qm) / qs
    # correlation of t with reversed qz via FFT
    size = 1 << int(np.ceil(np.log2(n + m)))
    fq = np.fft.rfft(qz[::-1], size)
    ft = np.fft.rfft(t, size)
    corr = np.fft.irfft(fq * ft, size)[m - 1 : n]
    mu, sd = _sliding_stats(t, m)
    # z-normalized dot product: (corr - m*mu*mean(qz)) / sd ; mean(qz)=0
    dot = corr / np.maximum(sd, 1e-10)
    d2 = np.maximum(2.0 * (m - dot), 0.0)
    return np.sqrt(d2)


def matrix_profile(series: np.ndarray, m: int, exclusion: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Self-join matrix profile: for each window, distance to its nearest
    non-trivial neighbour.  STOMP-style loop over windows using MASS rows.

    Returns ``(profile, profile_index)``.
    """
    t = np.asarray(series, np.float64)
    n = len(t) - m + 1
    if n <= 1:
        return np.zeros(max(n, 0)), np.zeros(max(n, 0), np.int64)
    excl = exclusion if exclusion is not None else max(1, m // 2)
    prof = np.full(n, np.inf)
    pidx = np.zeros(n, np.int64)
    for i in range(n):
        d = mass(t[i : i + m], t)
        lo, hi = max(0, i - excl), min(n, i + excl + 1)
        d[lo:hi] = np.inf
        j = int(np.argmin(d))
        prof[i] = d[j]
        pidx[i] = j
    return prof, pidx


@register_op("activity_series", needs_structure=True)
def activity_series(trace, num_bins: int = 512, process: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Binned total exclusive time (all functions) — the time-series signal
    pattern detection runs on.

    Args:
        num_bins: equal-width time bins over the trace span.
        process: restrict to one process id (None = all processes).

    Returns:
        ``(series, bin_edges)``: ``series`` has ``num_bins`` summed
        ``time.exc`` values (ns per bin, attributed to each call's Enter
        timestamp), ``bin_edges`` has ``num_bins + 1`` ns boundaries.
    """
    ev = trace.events
    trace._ensure_structure()
    ts = np.asarray(ev[TS], np.float64)
    sel = ev.cat(ET).mask_eq(ENTER)
    if process is not None:
        sel &= np.asarray(ev[PROC], np.int64) == process
    rows = np.nonzero(sel)[0]
    w = np.nan_to_num(np.asarray(ev.column(EXC), np.float64)[rows])
    t0, t1 = float(ts.min()), float(ts.max())
    edges = np.linspace(t0, max(t1, t0 + 1), num_bins + 1)
    series, _ = np.histogram(ts[rows], bins=edges, weights=w)
    return series, edges


@register_op("detect_pattern", needs_structure=True)
def detect_pattern(trace, start_event: Optional[str] = None, num_bins: int = 512,
                   process: int = 0, max_patterns: int = 64,
                   min_similarity: float = 0.8) -> List[EventFrame]:
    """Find repeating program phases (§IV-D, Fig. 8 — iteration detection).

    If ``start_event`` is given, occurrences of that function delimit
    candidate iterations; the matrix profile of the binned activity series
    confirms which candidates are genuinely similar.  Without a hint, the
    motif period is inferred from the matrix profile's best motif pair.

    Args:
        start_event: function name whose Enter events delimit candidate
            iterations (e.g. the paper's ``"time-loop"``); None infers the
            period automatically.
        num_bins: resolution of the activity series the similarity check
            runs on.
        process: process id whose timeline anchors the candidates.
        max_patterns: stop after this many accepted occurrences.
        min_similarity: z-normalized correlation (−1..1) a candidate must
            reach against the first occurrence's signal to be kept.

    Returns:
        List of EventFrames, one per detected occurrence — each a
        time-windowed slice of ``trace.events`` (all processes included).
        Empty list when no repetition is found.
    """
    ev = trace.events
    trace._ensure_structure()
    ts = np.asarray(ev[TS], np.float64)
    series, edges = activity_series(trace, num_bins=num_bins, process=process)
    bw = edges[1] - edges[0]

    if start_event is not None:
        name = ev.cat(NAME)
        sel = (name.mask_eq(start_event) & ev.cat(ET).mask_eq(ENTER)
               & (np.asarray(ev[PROC], np.int64) == process))
        starts = np.sort(ts[np.nonzero(sel)[0]])
        if len(starts) < 2:
            return []
        bounds = np.concatenate([starts, [ts.max()]])
    else:
        # infer period: motif = argmin of matrix profile, period = |i - j|
        m = max(4, num_bins // 16)
        prof, pidx = matrix_profile(series, m)
        i = int(np.argmin(prof))
        period = abs(int(pidx[i]) - i)
        if period == 0:
            return []
        first = i % period
        k = (num_bins - first) // period
        bounds = edges[0] + bw * (first + period * np.arange(k + 1))

    # validate candidate windows against the first occurrence's signal
    out: List[EventFrame] = []
    ref_sig = None
    for a, b in zip(bounds[:-1], bounds[1:]):
        if len(out) >= max_patterns:
            break
        lo = int(np.clip((a - edges[0]) / bw, 0, num_bins - 1))
        hi = int(np.clip((b - edges[0]) / bw, lo + 1, num_bins))
        sig = series[lo:hi]
        if ref_sig is None:
            ref_sig = sig
        else:
            L = min(len(sig), len(ref_sig))
            if L >= 2:
                x = (sig[:L] - sig[:L].mean()) / max(sig[:L].std(), 1e-10)
                y = (ref_sig[:L] - ref_sig[:L].mean()) / max(ref_sig[:L].std(), 1e-10)
                if float(np.mean(x * y)) < min_similarity:
                    continue
        window = (ts >= a) & (ts < b)
        out.append(ev.mask(window))
    return out
