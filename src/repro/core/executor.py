"""Multi-core execution of streaming TraceQuery plans (paper §VI scaled out).

The out-of-core engine (:mod:`repro.core.streaming`) runs a fused plan mask
plus a combinable aggregator chunk by chunk — serially, in one Python
process, leaving every other core idle on multi-GB traces.  This module is
the parallel driver on top of the *same* plan machinery:

* **unit planning** — the input is partitioned into independent work units
  in stream order: whole shard paths, byte ranges of line-oriented files
  (:class:`~repro.core.registry.ByteSpan`, planned by the format's
  registered ``plan_units``), or process subsets
  (:class:`~repro.core.registry.ProcSpan`, enforced with an explicit mask
  — reader hints stay advisory);
* **worker fold** — each unit runs the identical serial pipeline (pushdown
  hints → fused mask per chunk → streaming aggregator), with the
  :class:`~repro.core.streaming.CallStitcher` in *deferred* mode: events a
  unit cannot resolve locally (a Leave whose Enter lives in an earlier
  unit, call time owed to a call opened upstream) are recorded as **seam
  events** instead of being dropped;
* **merge** — the parent interns worker name tables in unit order
  (reproducing the serial first-seen code space), folds each worker's
  partial aggregate in through the op's declared
  :meth:`~repro.core.streaming.StreamAgg.merge_from`, and replays the seam
  events against the carry stacks of the preceding units — so enter/leave
  pairs split across unit seams complete with exactly the inclusive /
  exclusive attribution the serial stitcher produces.

Because every partial is a sum of integer-ns (or integer-count) values,
merge order cannot change a bit: results are byte-identical to serial
streaming for all exactly-combinable ops (``time_profile`` agrees to
float64 rounding, the same caveat it already carries vs eager execution).

Degradations back to serial streaming raise :class:`ParallelDegraded`
internally; ``execute_streaming`` converts that into a warning naming the
concrete reason (non-mergeable op, spawn-unsafe ``__main__``, nothing to
fan out, unsplittable input).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import registry
from .constants import ENTER, ET, INSTANT, LEAVE, NAME, PROC, TS
from .frame import Categorical, EventFrame
from .streaming import (CallBlock, CallStitcher, Chunk, GlobalNames,
                        StreamAgg, StreamContext, StreamStats,
                        StreamingUnsupported, _steps_hints, fold_frames,
                        iter_chunks_fallback, mask_frames, stats_from_frames)
from ..parallel_util import resolve_processes, spawn_unsafe_reason

__all__ = ["execute_parallel", "plan_units", "ParallelDegraded"]


class ParallelDegraded(RuntimeError):
    """Parallel execution is not applicable; fall back to serial streaming.
    The message is the user-facing reason (it ends up in a warning)."""


# ---------------------------------------------------------------------------
# unit planning
# ---------------------------------------------------------------------------

def _path_bytes(path: str) -> int:
    if os.path.isdir(path):
        total = 0
        for root, _dirs, files in os.walk(path):
            for f in files:
                try:
                    total += os.path.getsize(os.path.join(root, f))
                except OSError:  # pragma: no cover - racing deletes
                    pass
        return total
    try:
        return os.path.getsize(path)
    except OSError:
        return 0


def plan_units(handle, steps: Sequence, n_workers: int) -> List[Any]:
    """Partition the handle's (shard-skipped) input into work units.

    Units come back in stream order — path order, byte spans in offset
    order — which is what makes cross-unit seam replay equivalent to the
    serial chunk sequence.  A unit is a whole path (str), a
    :class:`~repro.core.registry.ByteSpan`, or a
    :class:`~repro.core.registry.ProcSpan`.

    Plans are memoized on the handle per (selected paths + their stat,
    n_workers): planners can be expensive (chrome's pid pre-pass decodes
    the stream), and every terminal op re-plans otherwise.  The per-path
    (size, mtime_ns) in the key means a file that grows between ops
    re-plans — byte spans computed against the old extent would silently
    truncate it.
    """
    import os as _os
    from .. import readers  # noqa: F401 — populate the registry
    from ..readers.parallel import select_shards
    hints = _steps_hints(steps)
    procs = set(hints.procs) if hints.procs is not None else None
    paths = select_shards(handle.paths, handle.format, procs=procs,
                          proc_bounds=hints.proc_bounds)
    if not paths:
        return []

    def _stat(p):
        # directories (otf2j archives) must reflect in-place rewrites of
        # contained files — the dir's own mtime only tracks entry add/remove
        try:
            if _os.path.isdir(p):
                size = mtime = n = 0
                for root, _dirs, files in _os.walk(p):
                    for fn in files:
                        st = _os.stat(_os.path.join(root, fn))
                        size += st.st_size
                        mtime = max(mtime, st.st_mtime_ns)
                        n += 1
                return (size, mtime, n)
            st = _os.stat(p)
            return (st.st_size, st.st_mtime_ns)
        except OSError:
            return (-1, -1)

    cache = getattr(handle, "_units_cache", None)
    if cache is None:
        cache = handle._units_cache = {}
    cache_key = (tuple((p,) + _stat(p) for p in paths), n_workers)
    if cache_key in cache:
        return cache[cache_key]
    sizes = [_path_bytes(p) for p in paths]
    total = max(sum(sizes), 1)
    units: List[Any] = []
    planner = getattr(handle, "plan_units_for", None)
    for p, sz in zip(paths, sizes):
        # shares of the worker budget proportional to file size
        want = max(1, round(sz * n_workers / total))
        if planner is not None:
            # handle-owned planning (live handles): the units it returns
            # are authoritative even when there is only one — a whole-path
            # unit would read past the pinned snapshot watermark
            units.extend(planner(p, want))
            continue
        spec = registry.resolve_reader(p, handle.format)
        sub = None
        if want > 1 and spec.plan_units is not None:
            sub = spec.plan_units(p, want)
        if sub and len(sub) > 1:
            units.extend(sub)
        else:
            units.append(p)
    cache[cache_key] = units
    return units


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------

def _unit_frames(unit, fmt: str, chunk_rows: int,
                 hints: Optional[registry.PlanHints],
                 reader_kwargs: dict) -> Iterator[EventFrame]:
    """Raw chunk frames of one work unit (pushdown hints applied)."""
    if isinstance(unit, registry.ByteSpan):
        spec = registry.resolve_reader(unit.path, fmt)
        yield from spec.iter_chunks(unit.path, chunk_rows, hints,
                                    byte_range=(unit.lo, unit.hi),
                                    **reader_kwargs)
        return
    if isinstance(unit, registry.RowSpan):
        # random-access columnar unit (pack): the reader slices rows
        # directly, no boundary alignment needed
        spec = registry.resolve_reader(unit.path, fmt)
        yield from spec.iter_chunks(unit.path, chunk_rows, hints,
                                    row_range=(unit.lo, unit.hi),
                                    **reader_kwargs)
        return
    if isinstance(unit, registry.ProcSpan):
        spec = registry.resolve_reader(unit.path, fmt)
        pset = frozenset(unit.procs)
        if hints is not None and hints.procs is not None:
            pset = pset & hints.procs
        sub = registry.PlanHints(
            procs=pset,
            proc_bounds=hints.proc_bounds if hints else None,
            time_window=hints.time_window if hints else None)
        kw = dict(unit.extra)
        kw.update(reader_kwargs)
        parr = np.asarray(sorted(pset), np.int64)
        for frame in spec.iter_chunks(unit.path, chunk_rows, sub, **kw):
            # hints are advisory; the unit's process subset is a partition
            # contract, so enforce it here
            m = np.isin(np.asarray(frame[PROC], np.int64), parr)
            yield frame if m.all() else frame.mask(m)
        return
    spec = registry.resolve_reader(unit, fmt)
    if spec.iter_chunks is not None:
        yield from spec.iter_chunks(unit, chunk_rows, hints, **reader_kwargs)
    else:
        yield from iter_chunks_fallback(unit, chunk_rows, hints, spec.read,
                                        **reader_kwargs)


class _UnitResult:
    """What one worker sends back: its name table (first-seen order), the
    updated aggregator, and — for call-stitching ops — the seam events,
    trailing open frames, and per-group time span."""

    __slots__ = ("names", "agg", "proc_max", "seams", "trailing",
                 "first_ts", "last_ts")

    def __init__(self, names, agg, proc_max, seams, trailing, first_ts,
                 last_ts):
        self.names = names
        self.agg = agg
        self.proc_max = proc_max
        self.seams = seams
        self.trailing = trailing
        self.first_ts = first_ts
        self.last_ts = last_ts

    def __getstate__(self):
        return {s: getattr(self, s) for s in self.__slots__}

    def __setstate__(self, state):
        for s in self.__slots__:
            setattr(self, s, state[s])


def _run_unit(payload) -> Any:
    """Pool worker: run one unit through the serial streaming pipeline.

    ``mode="stats"`` folds the unit into a StreamStats partial;
    ``mode="fold"`` builds the op's aggregator, feeds the unit's masked
    chunks through a deferring CallStitcher, and returns a _UnitResult.
    """
    (mode, unit, fmt, chunk_rows, reader_kwargs, steps, factory, args,
     kwargs, stats, label) = payload
    from ..readers import parallel as _rp
    _rp._ensure_registered()
    hints = _steps_hints(steps)
    frames = mask_frames(
        _unit_frames(unit, fmt, chunk_rows, hints, reader_kwargs),
        steps, label)
    if mode == "stats":
        return stats_from_frames(frames)
    agg: StreamAgg = factory(*args, **kwargs)
    agg.begin(stats)
    names = GlobalNames()
    stitcher = CallStitcher(defer_unmatched=True) if agg.needs_calls else None
    proc_max = fold_frames(frames, agg, names, stitcher)
    if stitcher is not None:
        first_ts, last_ts = stitcher.group_span()
        return _UnitResult(names.names, agg, proc_max, stitcher.seams(),
                           stitcher.trailing(), first_ts, last_ts)
    return _UnitResult(names.names, agg, proc_max, {}, {}, {}, {})


# ---------------------------------------------------------------------------
# parent side: merge
# ---------------------------------------------------------------------------

def _empty_events() -> EventFrame:
    """Canonical zero-row frame (uniform columns) carrying seam-completed
    calls into an aggregator update."""
    return EventFrame({
        TS: np.asarray([], np.int64),
        ET: Categorical.from_codes(np.asarray([], np.int32),
                                   np.asarray([ENTER, LEAVE, INSTANT])),
        NAME: Categorical.from_codes(np.asarray([], np.int32),
                                     np.asarray([], dtype=object)),
        PROC: np.asarray([], np.int64),
    })


def _merge_results(agg: StreamAgg, stats: Optional[StreamStats],
                   results: Sequence[_UnitResult]) -> Any:
    """Fold worker results, in unit order, into one finalized op result."""
    names = GlobalNames()
    agg.begin(stats)
    proc_max = -1
    # per-group carry stacks across unit seams: [name, proc, start, child_inc]
    prefix: Dict[int, List[list]] = {}
    last_ts: Dict[int, float] = {}
    for r in results:
        code_map = np.asarray([names.intern(str(s)) for s in r.names],
                              np.int64)
        for g, ft in r.first_ts.items():
            lt = last_ts.get(g)
            if lt is not None and ft < lt:
                raise StreamingUnsupported(
                    "streaming execution needs each (process, thread) event "
                    "stream in non-decreasing time order across parallel "
                    "work units; this trace interleaves out of order.  "
                    "Re-shard it or open with streaming=False.")
        for g, lt in r.last_ts.items():
            if lt > last_ts.get(g, -np.inf):
                last_ts[g] = lt
        # replay this unit's seam events against the upstream carry stacks
        completed: List[tuple] = []
        for g, items in r.seams.items():
            stack = prefix.setdefault(g, [])
            for item in items:
                if item[0] == "a":
                    if stack:
                        stack[-1][3] += item[1]
                    # no open call upstream: the serial stitcher drops the
                    # attribution too
                else:
                    _tag, ts_, _proc = item
                    if stack:
                        nm, pc, st_, ci = stack.pop()
                        inc = ts_ - st_
                        completed.append((nm, pc, st_, ts_, inc, inc - ci))
                        if stack:
                            stack[-1][3] += inc
                    # else: Leave with no open call anywhere — unmatched in
                    # the serial path as well; ignore
        # trailing open frames stack on top for the next units (name codes
        # remapped into the merged space now, so later pops need no map)
        for g, frames_ in r.trailing.items():
            stack = prefix.setdefault(g, [])
            for nm, pc, st_ts, ci in frames_:
                stack.append([int(code_map[nm]), int(pc), float(st_ts),
                              float(ci)])
        agg.merge_from(r.agg, code_map)
        if completed:
            cn, cp, cs, ce, ci_, cx = (np.asarray(c)
                                       for c in zip(*completed))
            block = CallBlock(cn.astype(np.int64), cp.astype(np.int64),
                              cs.astype(np.float64), ce.astype(np.float64),
                              ci_.astype(np.float64), cx.astype(np.float64))
            agg.update(Chunk(_empty_events(), np.empty(0, np.int64), block,
                             names))
        proc_max = max(proc_max, r.proc_max)
    open_frames = [f for st in prefix.values() for f in st]
    open_calls = (np.asarray([f[0] for f in open_frames], np.int64),
                  np.asarray([f[1] for f in open_frames], np.int64))
    ctx = StreamContext(names, stats, open_calls, proc_max)
    return agg.result(ctx)


def _prune_units(units: List[Any], hints: registry.PlanHints) -> List[Any]:
    """Drop ProcSpan units whose process set the plan's restriction can
    never admit — their workers would decode the whole stream just to mask
    every row away.  Safe because ProcSpan sets partition the rows: a
    dropped unit contributes nothing under the plan mask."""
    if hints.procs is None and hints.proc_bounds is None:
        return units
    return [u for u in units
            if not isinstance(u, registry.ProcSpan)
            or any(hints.admits_proc(p) for p in u.procs)]


def parallel_stats(handle, steps: Sequence) -> StreamStats:
    """Run the StreamStats pre-pass over work units in the handle's pool.

    Raises :class:`ParallelDegraded` when fan-out is not applicable — the
    caller (``StreamingTrace.stats``) silently falls back to the serial
    pass, since a stats pass has no user-facing mode choice to warn about.
    """
    n = resolve_processes(handle.processes)
    if n <= 1:
        raise ParallelDegraded("processes=1 leaves nothing to fan out")
    units = _prune_units(plan_units(handle, steps, n), _steps_hints(steps))
    if len(units) <= 1:
        raise ParallelDegraded("input cannot be partitioned")
    reason = spawn_unsafe_reason()
    if reason is not None:
        raise ParallelDegraded(reason)
    if handle._pool is None:
        from .scheduler import get_scheduler
        handle._pool = get_scheduler().spawn_pool(n)
    payloads = [("stats", u, handle.format, handle.chunk_rows,
                 handle.reader_kwargs, tuple(steps), None, (), {}, None,
                 handle.label) for u in units]
    stats = StreamStats()
    for part in handle._pool.map(_run_unit, payloads):
        stats.merge(part)
    return stats


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def execute_parallel(handle, steps: Sequence, spec: registry.OpSpec,
                     args: tuple, kwargs: dict, agg: StreamAgg,
                     n_units: Optional[int] = None,
                     use_pool: bool = True) -> Any:
    """Fan one streaming op over work units and merge the partials.

    Raises :class:`ParallelDegraded` (with the user-facing reason) whenever
    multi-core execution is not applicable; the caller falls back to the
    serial path and warns.  ``n_units``/``use_pool`` exist for tests: they
    force a unit count and run workers in-process, exercising the seam
    machinery without pool startup cost.
    """
    if not getattr(agg, "supports_parallel", False):
        raise ParallelDegraded(
            f"op {spec.name!r} has a streaming form but no cross-worker "
            f"merge declaration (aggregator {type(agg).__name__}); it runs "
            f"serially")
    n = resolve_processes(handle.processes)
    if use_pool and n <= 1:
        raise ParallelDegraded("processes=1 leaves nothing to fan out")
    units = _prune_units(plan_units(handle, steps, n_units or n),
                         _steps_hints(steps))
    if len(units) <= 1:
        raise ParallelDegraded(
            "the input cannot be partitioned into more than one work unit "
            "(single file with no registered unit planner, or everything "
            "was pruned by shard skipping / the plan's process "
            "restriction)")
    if use_pool:
        reason = spawn_unsafe_reason()
        if reason is not None:
            raise ParallelDegraded(reason)
        if handle._pool is None:
            # pool ownership lives in the shared scheduler: every handle
            # (and every trace-query service session) asking for n workers
            # fans into the same spawn pool, so worker startup is paid once
            # per process, not once per handle
            from .scheduler import get_scheduler
            handle._pool = get_scheduler().spawn_pool(n)
        try:
            handle._pool.get()
        except RuntimeError as e:  # pragma: no cover - raced __main__ state
            raise ParallelDegraded(str(e)) from None
        mapper = lambda payloads: handle._pool.map(_run_unit, payloads)  # noqa: E731
    else:
        mapper = lambda payloads: [_run_unit(p) for p in payloads]  # noqa: E731

    def payload(mode, unit, stats=None):
        return (mode, unit, handle.format, handle.chunk_rows,
                handle.reader_kwargs, tuple(steps), spec.streaming, args,
                kwargs, stats, handle.label)

    stats = None
    if agg.needs_stats:
        if tuple(steps) == tuple(handle._steps) and handle._stats0 is not None:
            stats = handle._stats0
        else:
            stats = StreamStats()
            for part in mapper([payload("stats", u) for u in units]):
                stats.merge(part)
            if tuple(steps) == tuple(handle._steps):
                handle._stats0 = stats
    results = mapper([payload("fold", u, stats) for u in units])
    return _merge_results(agg, stats, results)
