"""Plan-result cache: terminal-op results memoized by content identity.

Repeated interactive analysis — the notebook workflow the paper's scripting
pitch targets — re-runs the same terminal ops over the same traces
constantly, and for out-of-core handles every re-run is a full re-read of
the on-disk stream.  This cache memoizes terminal-op results keyed by a
digest of

    (trace content identity, fused plan steps, op identity, args, kwargs)

so a repeated call returns the previous result object without touching the
data.  Entries are shared process-wide: two TraceSet members over the same
paths, or two handles opened on the same file, hit the same entry.

Content identity is what makes this safe:

* **streaming / scan sources** — the (path, size, mtime_ns, inode) of every
  input file plus the handle's read configuration; touching or rewriting a
  file changes the key, so stale hits are impossible.  On by default
  (``Trace.open(..., cache=False)`` or a per-call ``op(..., cache=False)``
  opts out).
* **in-memory traces** — a SHA-256 over the trace's base event columns
  (derived columns excluded: they are deterministic products of the base
  and materialize lazily).  Hashing is O(N) per call, so this layer is
  **opt-in** per call (``trace.query().flat_profile(cache=True)``); caching
  stays exact under mutation because a mutated frame hashes differently.

Anything that cannot be digested exactly — callable arguments, unknown
custom plan steps, exotic values — silently bypasses the cache rather than
risking a wrong hit.  ``clear()`` is the explicit invalidation hatch;
``configure(enabled=False)`` turns the whole layer off.

Like ``functools.lru_cache``, hits return the *same object* that was
stored: treat cached results as read-only, since mutating a returned
frame/array in place would be visible to every later hit.  Call with
``cache=False`` (or ``.copy()`` the result) when you intend to mutate.
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import numpy as np

__all__ = ["lookup", "store", "plan_key", "clear", "configure", "stats",
           "live_lookup", "live_store", "live_invalidate", "live_plan_key"]

_MAX_ENTRIES = 128
_ENABLED = True
_TENANT_QUOTA: Optional[int] = None  # max entries per tenant; None = no cap
_CACHE: "OrderedDict[str, Any]" = OrderedDict()
_OWNER: Dict[str, str] = {}          # key -> tenant (tagged entries only)
_TENANT_KEYS: Dict[str, "OrderedDict[str, None]"] = {}  # tenant -> key LRU
_HITS = 0
_MISSES = 0
_EVICTIONS = 0
_TENANT_STATS: Dict[str, Dict[str, int]] = {}

# Live incremental partials (valid-up-to-row semantics): a live handle's
# plan keeps its running aggregation state here, keyed by live_plan_key —
# a re-query after the trace grows folds only the new rows into the
# stored partial instead of recomputing from row 0.  Validity is enforced
# by per-path prefix fingerprints stored *inside* the entry (group count,
# end offset, last CRC), not by the key: the same key deliberately
# matches across growth.  See core/streaming.py::execute_streaming.
_LIVE: "OrderedDict[str, Any]" = OrderedDict()
_LIVE_MAX = 32
_LIVE_HITS = 0
_LIVE_MISSES = 0
_LIVE_INVALIDATIONS = 0

# One process-wide reentrant lock guards every counter and both index maps:
# the trace-query service looks up / stores from worker threads while the
# asyncio loop reads stats(), and library calls can race them from the main
# thread.  All critical sections are tiny (dict ops), so a single lock
# cannot become the bottleneck next to the plan executions it memoizes.
_LOCK = threading.RLock()


class _Undigestable(Exception):
    """A key component has no exact digest; bypass the cache."""


def _tenant_stats(tenant: str) -> Dict[str, int]:
    st = _TENANT_STATS.get(tenant)
    if st is None:
        st = _TENANT_STATS[tenant] = {"entries": 0, "hits": 0, "misses": 0,
                                      "evictions": 0}
    return st


def _forget(key: str) -> None:
    """Drop ``key``'s tenant bookkeeping (caller already popped _CACHE)."""
    tenant = _OWNER.pop(key, None)
    if tenant is not None:
        keys = _TENANT_KEYS.get(tenant)
        if keys is not None:
            keys.pop(key, None)
        st = _tenant_stats(tenant)
        st["entries"] = max(st["entries"] - 1, 0)
        st["evictions"] += 1


def _evict_oldest() -> None:
    global _EVICTIONS
    key, _ = _CACHE.popitem(last=False)
    _forget(key)
    _EVICTIONS += 1


def configure(enabled: Optional[bool] = None,
              max_entries: Optional[int] = None,
              tenant_quota: Optional[int] = None) -> None:
    """Adjust the cache globally (``enabled=False`` disables lookups and
    stores; ``max_entries`` bounds the LRU; ``tenant_quota`` caps the
    entries any one tenant tag may hold — 0/negative removes the cap)."""
    global _ENABLED, _MAX_ENTRIES, _TENANT_QUOTA
    with _LOCK:
        if enabled is not None:
            _ENABLED = bool(enabled)
        if max_entries is not None:
            _MAX_ENTRIES = max(int(max_entries), 1)
            while len(_CACHE) > _MAX_ENTRIES:
                _evict_oldest()
        if tenant_quota is not None:
            _TENANT_QUOTA = int(tenant_quota) if tenant_quota > 0 else None
            if _TENANT_QUOTA is not None:
                for tenant in list(_TENANT_KEYS):
                    _shrink_tenant(tenant)


def _shrink_tenant(tenant: str) -> None:
    global _EVICTIONS
    keys = _TENANT_KEYS.get(tenant)
    if keys is None or _TENANT_QUOTA is None:
        return
    while len(keys) > _TENANT_QUOTA:
        key, _ = keys.popitem(last=False)
        _CACHE.pop(key, None)
        _OWNER.pop(key, None)
        st = _tenant_stats(tenant)
        st["entries"] = max(st["entries"] - 1, 0)
        st["evictions"] += 1
        _EVICTIONS += 1


def clear() -> None:
    """Drop every cached result (explicit invalidation), including live
    incremental partials.  Counters and per-tenant usage tallies survive;
    only the entries go."""
    with _LOCK:
        _CACHE.clear()
        _OWNER.clear()
        _TENANT_KEYS.clear()
        _LIVE.clear()
        for st in _TENANT_STATS.values():
            st["entries"] = 0


def stats() -> dict:
    """Cache counters: entries, hits, misses, evictions, limits, and — for
    entries stored under a tenant tag (the trace-query service does this) —
    per-tenant usage.  The service exposes this verbatim on ``/stats``."""
    with _LOCK:
        return {"entries": len(_CACHE), "hits": _HITS, "misses": _MISSES,
                "evictions": _EVICTIONS, "max_entries": _MAX_ENTRIES,
                "enabled": _ENABLED, "tenant_quota": _TENANT_QUOTA,
                "live_entries": len(_LIVE), "live_hits": _LIVE_HITS,
                "live_misses": _LIVE_MISSES,
                "live_invalidations": _LIVE_INVALIDATIONS,
                "tenants": {t: dict(st) for t, st in _TENANT_STATS.items()}}


def live_lookup(key: str) -> Any:
    """The stored incremental partial for ``key``, or None.  The caller
    owns validity checking (prefix fingerprints live in the entry)."""
    global _LIVE_HITS, _LIVE_MISSES
    with _LOCK:
        ent = _LIVE.get(key)
        if ent is not None:
            _LIVE.move_to_end(key)
            _LIVE_HITS += 1
            return ent
        _LIVE_MISSES += 1
        return None


def live_store(key: str, entry: Any) -> None:
    with _LOCK:
        _LIVE[key] = entry
        _LIVE.move_to_end(key)
        while len(_LIVE) > _LIVE_MAX:
            _LIVE.popitem(last=False)


def live_invalidate(key: Optional[str] = None) -> None:
    """Drop one live partial (or all of them) — used when a shard's
    committed prefix stops being a prefix extension (resume truncated a
    tail, a file was replaced) and on explicit invalidation."""
    global _LIVE_INVALIDATIONS
    with _LOCK:
        if key is None:
            _LIVE_INVALIDATIONS += len(_LIVE)
            _LIVE.clear()
        elif _LIVE.pop(key, None) is not None:
            _LIVE_INVALIDATIONS += 1


def lookup(key: str, tenant: Optional[str] = None) -> Tuple[bool, Any]:
    """(hit, value) for ``key``; a hit refreshes LRU order.  ``tenant``
    attributes the hit/miss to that tenant's usage counters."""
    global _HITS, _MISSES
    with _LOCK:
        if key in _CACHE:
            _CACHE.move_to_end(key)
            if tenant is not None:
                keys = _TENANT_KEYS.get(tenant)
                if keys is not None and key in keys:
                    keys.move_to_end(key)
                _tenant_stats(tenant)["hits"] += 1
            _HITS += 1
            return True, _CACHE[key]
        _MISSES += 1
        if tenant is not None:
            _tenant_stats(tenant)["misses"] += 1
        return False, None


def store(key: str, value: Any, tenant: Optional[str] = None) -> None:
    """Insert ``key``.  With a ``tenant`` tag the entry counts toward that
    tenant's quota (oldest tagged entry evicted beyond it); untagged
    entries (plain library calls) only face the global LRU bound."""
    with _LOCK:
        if key in _CACHE:
            _CACHE[key] = value
            _CACHE.move_to_end(key)
            return
        _CACHE[key] = value
        if tenant is not None:
            _OWNER[key] = tenant
            _TENANT_KEYS.setdefault(tenant, OrderedDict())[key] = None
            _tenant_stats(tenant)["entries"] += 1
            _shrink_tenant(tenant)
        while len(_CACHE) > _MAX_ENTRIES:
            _evict_oldest()


# ---------------------------------------------------------------------------
# key construction
# ---------------------------------------------------------------------------

def _norm(v) -> Any:
    """Normalize one argument value into a deterministic, repr-stable
    token; raise _Undigestable for anything without an exact digest."""
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return v
    if isinstance(v, (np.integer, np.floating, np.bool_)):
        return v.item()
    if isinstance(v, (list, tuple)):
        return tuple(_norm(x) for x in v)
    if isinstance(v, (set, frozenset)):
        return tuple(sorted((_norm(x) for x in v), key=repr))
    if isinstance(v, dict):
        return tuple(sorted(((str(k), _norm(x)) for k, x in v.items())))
    if isinstance(v, range):
        return ("range", v.start, v.stop, v.step)
    if isinstance(v, np.ndarray) and v.size <= 4096:
        return ("ndarray", v.dtype.str, v.shape, v.tobytes())
    raise _Undigestable(type(v).__name__)


def _filter_token(f) -> tuple:
    from .filters import _And, _Not, _Or
    if isinstance(f, _And):
        return ("and", _filter_token(f.a), _filter_token(f.b))
    if isinstance(f, _Or):
        return ("or", _filter_token(f.a), _filter_token(f.b))
    if isinstance(f, _Not):
        return ("not", _filter_token(f.a))
    if type(f).__name__ not in ("Filter",):
        raise _Undigestable(type(f).__name__)  # user Filter subclass
    return ("leaf", f.field, f.operator, _norm(f.value),
            getattr(f, "_trim", None))


def _steps_token(steps) -> tuple:
    from .query import FilterStep, ProcessStep, SliceTimeStep
    out = []
    for step in steps:
        if type(step) is FilterStep:
            out.append(("filter", _filter_token(step.filter)))
        elif type(step) is SliceTimeStep:
            out.append(("slice", float(step.start), float(step.end),
                        step.trim))
        elif type(step) is ProcessStep:
            out.append(("procs", tuple(int(p) for p in step.procs)))
        else:
            raise _Undigestable(type(step).__name__)
    return tuple(out)


def _stat_token(path: str) -> tuple:
    import os
    st = os.stat(path)
    # pack files carry a stored content id (SHA-256 over the column +
    # sidecar bytes): keying by it instead of (size, mtime, inode) means
    # copies and faithful rewrites of a pack share one cache entry, and a
    # re-pack with different content can never produce a stale hit
    from ..readers.pack import content_id
    cid = content_id(path)
    if cid is not None:
        return ("pipitpack", cid)
    return (path, st.st_size, st.st_mtime_ns, st.st_ino)


def _paths_token(paths) -> tuple:
    import os
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in sorted(os.walk(p)):
                out.extend(_stat_token(os.path.join(root, f))
                           for f in sorted(files))
        else:
            out.append(_stat_token(p))
    return tuple(out)


def _content_token(trace) -> tuple:
    """SHA-256 over the trace's base (non-derived) event columns."""
    from .frame import Categorical
    from .query import _strip
    ev = _strip(trace.events)
    h = hashlib.sha256()
    for name in ev.columns:
        col = ev.column(name)
        h.update(name.encode())
        if isinstance(col, Categorical):
            h.update(np.ascontiguousarray(col.codes).tobytes())
            h.update("\x00".join(map(str, col.categories)).encode())
        else:
            arr = np.asarray(col)
            if arr.dtype.kind == "O":
                raise _Undigestable(f"object column {name}")
            h.update(arr.dtype.str.encode())
            h.update(np.ascontiguousarray(arr).tobytes())
    return ("mem", len(ev), h.hexdigest())


def _source_token(source, cache_flag: Optional[bool]):
    """Identity token for a plan source, or None when this source should
    not be cached under the given per-call flag."""
    from .query import _ScanSource, _StreamSource, _TraceSource
    if isinstance(source, _StreamSource):
        h = source.handle
        if getattr(h, "is_live", False):
            # live handles execute over a pinned committed-prefix snapshot
            # — a stat-keyed entry would go stale the moment another
            # handle pins a newer snapshot of the same (unchanged) file.
            # They use the live incremental store instead.
            return None
        if cache_flag is None and not h.cache:
            return None
        return ("stream", _paths_token(h.paths), h.format, h.chunk_rows,
                h.executor, h.processes, _norm(h.reader_kwargs),
                _steps_token(h._steps))
    if isinstance(source, _ScanSource):
        return ("scan", _paths_token(source.paths), source.format)
    if isinstance(source, _TraceSource):
        # hashing an in-memory trace costs a full pass — only on request
        if not cache_flag:
            return None
        return _content_token(source.trace)
    return None  # unknown source kinds are never cached


def plan_key(source, steps, spec, args: tuple, kwargs: dict,
             cache_flag: Optional[bool]) -> Optional[str]:
    """Digest of one terminal-op execution, or None to bypass the cache.

    ``cache_flag`` is the per-call ``cache=`` argument: False forces a
    bypass, True opts an in-memory trace in, None applies the defaults
    (streaming/scan sources cached, in-memory not).
    """
    if not _ENABLED or cache_flag is False:
        return None
    try:
        src = _source_token(source, cache_flag)
        if src is None:
            return None
        fn = spec.fn
        op = (spec.name,
              f"{getattr(fn, '__module__', '')}."
              f"{getattr(fn, '__qualname__', '')}" if fn is not None else "")
        token = (src, _steps_token(steps), op, _norm(args), _norm(kwargs))
    except (_Undigestable, OSError):
        return None
    return hashlib.sha256(repr(token).encode()).hexdigest()


def live_plan_key(handle, steps, spec, args: tuple, kwargs: dict
                  ) -> Optional[str]:
    """Digest identifying one live plan *across growth*: the handle's
    paths and read configuration plus the plan/op/arguments — but
    deliberately **no** stat/content token, because the whole point is
    that the same key survives the file growing.  Validity (the new
    prefix really extends the one already folded) is checked against the
    fingerprints stored inside the live entry, never the key.  None when
    any component has no exact digest."""
    import os
    if not _ENABLED:
        return None
    try:
        rk = {k: v for k, v in handle.reader_kwargs.items()
              if k not in ("live", "upto_rows", "report")}
        fn = spec.fn
        op = (spec.name,
              f"{getattr(fn, '__module__', '')}."
              f"{getattr(fn, '__qualname__', '')}" if fn is not None else "")
        token = ("live",
                 tuple(os.path.abspath(p) for p in handle.paths),
                 handle.format, handle.chunk_rows, handle.processes,
                 _norm(rk), _steps_token(handle._steps),
                 _steps_token(steps), op, _norm(args), _norm(kwargs))
    except (_Undigestable, OSError):
        return None
    return hashlib.sha256(repr(token).encode()).hexdigest()
