"""The Trace object — Pipit's user-facing entry point (paper §III).

A Trace wraps the columnar events EventFrame plus lazily-derived structure
(enter/leave matching, call depth, caller/callee links, inclusive/exclusive
metrics, message matching, the unified CCT) and exposes every §IV analysis
operation as a method.  Readers live in :mod:`repro.readers` and are
re-exported here as ``Trace.from_*`` constructors; ``Trace.open`` resolves
any registered format by sniffing (see :mod:`repro.core.registry`).

Analysis methods and the data-reduction methods (``filter``, ``slice_time``,
``filter_processes``) are thin wrappers over one-step lazy query plans
(:mod:`repro.core.query`); chain them explicitly via :meth:`Trace.query` to
fuse selections and reuse derived structure across the chain.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

# ops_comm/ops_logical/ops_patterns/diff are load-bearing imports even where
# unreferenced below: importing them runs their @register_op decorators,
# which populate the registry every TraceQuery terminal op resolves through
from . import detectors, diff, ops_comm, ops_logical, ops_patterns, ops_summary, structure  # noqa: F401
from .cct import CCT
from .constants import (DEFAULT_IDLE_NAMES, ENTER, ET, EXC, INC, LEAVE, MATCH,
                        MATCH_TS, NAME, PARENT, PROC, TS)
from .filters import Filter
from .frame import EventFrame
from .query import TraceQuery, _strip as _strip_derived

__all__ = ["Trace"]


class Trace:
    """A parallel execution trace: events + derived structure + analysis API."""

    def __init__(self, events: EventFrame, definitions: Optional[dict] = None,
                 label: Optional[str] = None):
        self.events = events
        self.definitions = definitions or {}
        self.label = label
        self._structured = False
        self._cct: Optional[CCT] = None
        self._msg_match: Optional[np.ndarray] = None
        self._ingest = None  # IngestReport set by readers (see core.errors)

    # ------------------------------------------------------------------
    # constructors (delegate to repro.readers; imported lazily to avoid
    # circular imports)
    # ------------------------------------------------------------------
    @classmethod
    def from_csv(cls, path: str, **kw) -> "Trace":
        from ..readers.csvreader import read_csv
        return read_csv(path, **kw)

    @classmethod
    def from_jsonl(cls, path: str, **kw) -> "Trace":
        from ..readers.jsonl import read_jsonl
        return read_jsonl(path, **kw)

    @classmethod
    def from_chrome(cls, path: str, **kw) -> "Trace":
        from ..readers.chrome import read_chrome
        return read_chrome(path, **kw)

    @classmethod
    def from_otf2_json(cls, path: str, **kw) -> "Trace":
        from ..readers.otf2j import read_otf2_json
        return read_otf2_json(path, **kw)

    @classmethod
    def from_hlo(cls, hlo_text: str, **kw) -> "Trace":
        from ..readers.hlo import read_hlo
        return read_hlo(hlo_text, **kw)

    @classmethod
    def from_events(cls, events: EventFrame, label: Optional[str] = None) -> "Trace":
        return cls(events, label=label)

    @classmethod
    def open(cls, path, format: str = "auto", streaming: bool = False,
             live: bool = False, chunk_rows: Optional[int] = None,
             processes: Optional[int] = None, executor: str = "auto",
             cache: bool = True, **kw):
        """Open a trace of any registered format.

        ``format="auto"`` sniffs the on-disk content (CSV header, JSONL event
        keys, Chrome ``traceEvents`` envelope, OTF2-structured archives —
        file or directory — and HLO text).  A list of paths is read as
        per-location shards through the parallel driver (``processes=N``
        then fans the shard ingest over a pool).

        ``streaming=True`` returns a
        :class:`~repro.core.streaming.StreamingTrace` instead: an
        out-of-core handle that never materializes the trace — terminal
        analysis ops with a combinable streaming form execute chunk by
        chunk (at most ``chunk_rows`` events in memory per chunk), with the
        plan's predicate/process/time-window restriction pushed into the
        chunked readers.  ``processes=N`` / ``executor="parallel"`` fan
        those ops over multi-core work units (stitch-safe partitioning,
        byte-identical merges — see docs/streaming.md), and ``cache=False``
        opts the handle out of the plan-result cache
        (:mod:`repro.core.plancache`).

        ``live=True`` (implies streaming) returns a
        :class:`~repro.core.streaming.LiveTrace` over still-growing
        append-mode pack shards: plans execute over the committed prefix
        pinned at the last ``refresh()``, results carry a ``watermark``,
        and repeated queries fold only newly committed rows into a cached
        running aggregate — see docs/robustness.md § Live ingestion.
        """
        import os
        from .. import readers  # noqa: F401 — populates the reader registry
        from .registry import resolve_reader
        if live:
            from .streaming import DEFAULT_CHUNK_ROWS, LiveTrace
            return LiveTrace(path, format=format,
                             chunk_rows=chunk_rows or DEFAULT_CHUNK_ROWS,
                             processes=processes, executor=executor,
                             cache=cache, **kw)
        if streaming:
            from .streaming import DEFAULT_CHUNK_ROWS, StreamingTrace
            return StreamingTrace(path, format=format,
                                  chunk_rows=chunk_rows or DEFAULT_CHUNK_ROWS,
                                  processes=processes, executor=executor,
                                  cache=cache, **kw)
        if chunk_rows is not None:
            raise ValueError("chunk_rows only applies with streaming=True")
        if executor != "auto":
            raise ValueError("executor only applies with streaming=True")
        if cache is not True:
            # eager opens have no handle to opt out; per-call cache= on the
            # query terminal is the in-memory control
            raise ValueError("cache only applies with streaming=True; "
                             "in-memory caching is opt-in per call "
                             "(query terminal cache=True)")
        if isinstance(path, (list, tuple)):
            from ..readers.parallel import read_parallel
            return read_parallel([os.fspath(p) for p in path], kind=format,
                                 processes=processes, **kw)
        if processes is not None:
            raise ValueError("processes needs streaming=True or a list of "
                             "shard paths")
        path = os.fspath(path)
        return resolve_reader(path, format).read(path, **kw)

    # ------------------------------------------------------------------
    # serialization — the columnar binary store
    # ------------------------------------------------------------------
    def save_pack(self, path, chunk_rows: Optional[int] = None,
                  sidecar: bool = True) -> str:
        """Serialize this trace as a ``pipitpack`` columnar binary file.

        Reopening a pack (``Trace.open(path)``) memmaps each column with
        zero parsing; with ``sidecar=True`` (default) the derived structure
        (matching / depth / parent / inc / exc) is stored too, so the
        reopened trace skips ``derive_structure`` entirely.  Convert once,
        analyze fast — see docs/pack-format.md.  Returns ``path``.
        """
        import os
        from ..readers.pack import DEFAULT_PACK_CHUNK_ROWS, write_pack
        return write_pack(self, os.fspath(path),
                          chunk_rows=chunk_rows or DEFAULT_PACK_CHUNK_ROWS,
                          sidecar=sidecar)

    # ------------------------------------------------------------------
    # basics
    # ------------------------------------------------------------------
    def ingest_report(self):
        """The :class:`~repro.core.errors.IngestReport` from the read that
        produced this trace: exact per-path counts of surviving rows,
        skipped records and lost bytes.  Always clean for strict reads
        (they raise instead of dropping); a fresh empty report for traces
        not built by a reader."""
        from .errors import IngestReport
        if self._ingest is None:
            self._ingest = IngestReport()
        return self._ingest

    @property
    def num_processes(self) -> int:
        if len(self.events) == 0:
            return 0
        return int(np.asarray(self.events[PROC]).max()) + 1

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Trace(label={self.label!r}, events={len(self.events)}, "
                f"processes={self.num_processes})")

    # ------------------------------------------------------------------
    # derived structure (lazy, cached in the frame itself)
    # ------------------------------------------------------------------
    def _ensure_structure(self) -> None:
        if self._structured:
            return
        ev = self.events
        matching, depth, parent, inc, exc = structure.derive_structure(ev)
        ev[MATCH] = matching
        ev["_depth"] = depth
        ev[PARENT] = parent
        ev[INC] = inc
        ev[EXC] = exc
        ts = np.asarray(ev[TS], np.float64)
        ev[MATCH_TS] = np.where(matching >= 0, ts[np.maximum(matching, 0)], np.nan)
        self._structured = True

    def _ensure_messages(self) -> None:
        if self._msg_match is None:
            self._msg_match = structure.match_messages(self.events)

    # paper-named entry points -----------------------------------------
    def _match_caller_callee(self) -> None:
        self._ensure_structure()

    def calc_inc_metrics(self) -> None:
        self._ensure_structure()

    def calc_exc_metrics(self) -> None:
        self._ensure_structure()

    def _create_cct(self) -> CCT:
        return self.cct

    @property
    def cct(self) -> CCT:
        if self._cct is None:
            self._ensure_structure()
            self._cct = CCT.build(self.events,
                                  np.asarray(self.events.column(PARENT), np.int64),
                                  np.asarray(self.events.column("_depth")))
            self.events["_cct_node"] = self._cct.event_node
        return self._cct

    # ------------------------------------------------------------------
    # lazy query plans (§IV-E redesign)
    # ------------------------------------------------------------------
    def query(self) -> TraceQuery:
        """Start a lazy, composable query plan over this trace.

        Chained selections fuse into one mask; derived structure is remapped
        instead of recomputed when the selection keeps call pairs intact;
        analysis ops registered in :mod:`repro.core.registry` are terminal
        methods on the returned query.
        """
        return TraceQuery.from_trace(self)

    # ------------------------------------------------------------------
    # §IV-B summary ops — thin wrappers over one-step query plans
    # ------------------------------------------------------------------
    def flat_profile(self, metrics: Sequence[str] = (EXC,), per_process: bool = False,
                     groupby_column: str = NAME,
                     backend: str = "numpy") -> EventFrame:
        return self.query().run("flat_profile", metrics=metrics,
                                per_process=per_process,
                                groupby_column=groupby_column,
                                backend=backend)

    def time_profile(self, num_bins: int = 32, metric: str = EXC,
                     normalized: bool = False, backend: str = "numpy") -> EventFrame:
        return self.query().run("time_profile", num_bins=num_bins, metric=metric,
                                normalized=normalized, backend=backend)

    # ------------------------------------------------------------------
    # §IV-C communication ops
    # ------------------------------------------------------------------
    def comm_matrix(self, output: str = "size",
                    backend: str = "numpy") -> np.ndarray:
        return self.query().run("comm_matrix", output=output,
                                backend=backend)

    def message_histogram(self, bins: int = 10, backend: str = "numpy"
                          ) -> Tuple[np.ndarray, np.ndarray]:
        return self.query().run("message_histogram", bins=bins,
                                backend=backend)

    def comm_by_process(self, output: str = "size") -> EventFrame:
        return self.query().run("comm_by_process", output=output)

    def comm_over_time(self, num_bins: int = 32, output: str = "size"):
        return self.query().run("comm_over_time", num_bins=num_bins, output=output)

    def comm_comp_breakdown(self, comm_matcher: Optional[Callable[[str], bool]] = None
                            ) -> EventFrame:
        return self.query().run("comm_comp_breakdown", comm_matcher=comm_matcher)

    # ------------------------------------------------------------------
    # §IV-D performance-issue ops
    # ------------------------------------------------------------------
    def load_imbalance(self, metric: str = EXC, num_processes: int = 5,
                       top_functions: Optional[int] = None,
                       backend: str = "numpy") -> EventFrame:
        return self.query().run("load_imbalance", metric=metric,
                                num_processes=num_processes,
                                top_functions=top_functions,
                                backend=backend)

    def idle_time(self, idle_functions: Sequence[str] = DEFAULT_IDLE_NAMES,
                  k: Optional[int] = None) -> EventFrame:
        return self.query().run("idle_time", idle_functions=idle_functions, k=k)

    def detect_pattern(self, start_event: Optional[str] = None, **kw) -> List[EventFrame]:
        return self.query().run("detect_pattern", start_event=start_event, **kw)

    def calculate_lateness(self) -> EventFrame:
        return self.query().run("calculate_lateness")

    def lateness_by_process(self) -> EventFrame:
        return self.query().run("lateness_by_process")

    def critical_path_analysis(self) -> List[EventFrame]:
        return self.query().run("critical_path_analysis")

    # ------------------------------------------------------------------
    # automated diagnostics (repro.core.detectors)
    # ------------------------------------------------------------------
    def diagnose(self, detectors: Optional[Sequence[str]] = None) -> EventFrame:
        """Run every registered detector (or a named subset) and return one
        severity-ranked Findings frame — see ``docs/diagnostics.md``."""
        return self.query().run("diagnose", detectors=detectors)

    def efficiency_metrics(self, num_windows: int = 16) -> EventFrame:
        return self.query().run("efficiency_metrics", num_windows=num_windows)

    def late_sender(self, **kw) -> EventFrame:
        return self.query().run("late_sender", **kw)

    def stragglers(self, **kw) -> EventFrame:
        return self.query().run("stragglers", **kw)

    def serialization(self, **kw) -> EventFrame:
        return self.query().run("serialization", **kw)

    def imbalance_root_cause(self, **kw) -> EventFrame:
        return self.query().run("imbalance_root_cause", **kw)

    def pop_efficiency(self, **kw) -> EventFrame:
        return self.query().run("pop_efficiency", **kw)

    @staticmethod
    def multirun_analysis(traces: Sequence["Trace"], metric: str = EXC,
                          top_n: int = 16) -> EventFrame:
        for t in traces:
            t._ensure_structure()
        return ops_summary.multi_run_analysis(traces, metric=metric, top_n=top_n)

    # ------------------------------------------------------------------
    # §IV-E data reduction — one-step query plans (structure is remapped
    # through the selection when call pairs stay intact)
    # ------------------------------------------------------------------
    def filter(self, f: Filter) -> "Trace":
        """Subset trace by a Filter.  Time-window filters built with
        ``time_window_filter(..., trim="overlap")`` honor call-interval
        overlap semantics (the whole call is kept when any part of it
        overlaps the window)."""
        return self.query().filter(f).collect()

    def slice_time(self, start: float, end: float, trim: str = "overlap") -> "Trace":
        """Events whose call interval overlaps [start, end] (default), or whose
        own timestamp falls inside with trim="within"."""
        return self.query().slice_time(start, end, trim=trim).collect()

    def filter_processes(self, procs: Sequence[int]) -> "Trace":
        return self.query().restrict_processes(procs).collect()

    # row indices in derived columns are invalidated by row selection;
    # single implementation shared with the query engine
    _strip_structure = staticmethod(_strip_derived)

    # ------------------------------------------------------------------
    # visualization (delegates; matplotlib optional)
    # ------------------------------------------------------------------
    def plot_timeline(self, **kw):
        from . import viz
        return viz.plot_timeline(self, **kw)

    def plot_time_profile(self, **kw):
        from . import viz
        return viz.plot_time_profile(self, **kw)

    def plot_comm_matrix(self, **kw):
        from . import viz
        return viz.plot_comm_matrix(self, **kw)

    def plot_comm_by_process(self, **kw):
        from . import viz
        return viz.plot_comm_by_process(self, **kw)

    def plot_message_histogram(self, **kw):
        from . import viz
        return viz.plot_message_histogram(self, **kw)
