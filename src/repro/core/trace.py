"""The Trace object — Pipit's user-facing entry point (paper §III).

A Trace wraps the columnar events EventFrame plus lazily-derived structure
(enter/leave matching, call depth, caller/callee links, inclusive/exclusive
metrics, message matching, the unified CCT) and exposes every §IV analysis
operation as a method.  Readers live in :mod:`repro.readers` and are
re-exported here as ``Trace.from_*`` constructors.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from . import ops_comm, ops_logical, ops_patterns, ops_summary, structure
from .cct import CCT
from .constants import (DEFAULT_IDLE_NAMES, ENTER, ET, EXC, INC, LEAVE, MATCH,
                        MATCH_TS, NAME, PARENT, PROC, TS)
from .filters import Filter
from .frame import EventFrame

__all__ = ["Trace"]


class Trace:
    """A parallel execution trace: events + derived structure + analysis API."""

    def __init__(self, events: EventFrame, definitions: Optional[dict] = None,
                 label: Optional[str] = None):
        self.events = events
        self.definitions = definitions or {}
        self.label = label
        self._structured = False
        self._cct: Optional[CCT] = None
        self._msg_match: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # constructors (delegate to repro.readers; imported lazily to avoid
    # circular imports)
    # ------------------------------------------------------------------
    @classmethod
    def from_csv(cls, path: str, **kw) -> "Trace":
        from ..readers.csvreader import read_csv
        return read_csv(path, **kw)

    @classmethod
    def from_jsonl(cls, path: str, **kw) -> "Trace":
        from ..readers.jsonl import read_jsonl
        return read_jsonl(path, **kw)

    @classmethod
    def from_chrome(cls, path: str, **kw) -> "Trace":
        from ..readers.chrome import read_chrome
        return read_chrome(path, **kw)

    @classmethod
    def from_otf2_json(cls, path: str, **kw) -> "Trace":
        from ..readers.otf2j import read_otf2_json
        return read_otf2_json(path, **kw)

    @classmethod
    def from_hlo(cls, hlo_text: str, **kw) -> "Trace":
        from ..readers.hlo import read_hlo
        return read_hlo(hlo_text, **kw)

    @classmethod
    def from_events(cls, events: EventFrame, label: Optional[str] = None) -> "Trace":
        return cls(events, label=label)

    # ------------------------------------------------------------------
    # basics
    # ------------------------------------------------------------------
    @property
    def num_processes(self) -> int:
        if len(self.events) == 0:
            return 0
        return int(np.asarray(self.events[PROC]).max()) + 1

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Trace(label={self.label!r}, events={len(self.events)}, "
                f"processes={self.num_processes})")

    # ------------------------------------------------------------------
    # derived structure (lazy, cached in the frame itself)
    # ------------------------------------------------------------------
    def _ensure_structure(self) -> None:
        if self._structured:
            return
        ev = self.events
        matching, depth, order = structure.match_events(ev)
        parent = structure.compute_parents(ev, matching, depth, order)
        inc, exc = structure.compute_inc_exc(ev, matching, parent)
        ev[MATCH] = matching
        ev["_depth"] = depth
        ev[PARENT] = parent
        ev[INC] = inc
        ev[EXC] = exc
        ts = np.asarray(ev[TS], np.float64)
        ev[MATCH_TS] = np.where(matching >= 0, ts[np.maximum(matching, 0)], np.nan)
        self._structured = True

    def _ensure_messages(self) -> None:
        if self._msg_match is None:
            self._msg_match = structure.match_messages(self.events)

    # paper-named entry points -----------------------------------------
    def _match_caller_callee(self) -> None:
        self._ensure_structure()

    def calc_inc_metrics(self) -> None:
        self._ensure_structure()

    def calc_exc_metrics(self) -> None:
        self._ensure_structure()

    def _create_cct(self) -> CCT:
        return self.cct

    @property
    def cct(self) -> CCT:
        if self._cct is None:
            self._ensure_structure()
            self._cct = CCT.build(self.events,
                                  np.asarray(self.events.column(PARENT), np.int64),
                                  np.asarray(self.events.column("_depth")))
            self.events["_cct_node"] = self._cct.event_node
        return self._cct

    # ------------------------------------------------------------------
    # §IV-B summary ops
    # ------------------------------------------------------------------
    def flat_profile(self, metrics: Sequence[str] = (EXC,), per_process: bool = False,
                     groupby_column: str = NAME) -> EventFrame:
        self._ensure_structure()
        return ops_summary.flat_profile(self, metrics=metrics, per_process=per_process,
                                        groupby_column=groupby_column)

    def time_profile(self, num_bins: int = 32, metric: str = EXC,
                     normalized: bool = False, backend: str = "numpy") -> EventFrame:
        self._ensure_structure()
        return ops_summary.time_profile(self, num_bins=num_bins, metric=metric,
                                        normalized=normalized, backend=backend)

    # ------------------------------------------------------------------
    # §IV-C communication ops
    # ------------------------------------------------------------------
    def comm_matrix(self, output: str = "size") -> np.ndarray:
        self._ensure_messages()
        return ops_comm.comm_matrix(self, output=output)

    def message_histogram(self, bins: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        return ops_comm.message_histogram(self, bins=bins)

    def comm_by_process(self, output: str = "size") -> EventFrame:
        return ops_comm.comm_by_process(self, output=output)

    def comm_over_time(self, num_bins: int = 32, output: str = "size"):
        return ops_comm.comm_over_time(self, num_bins=num_bins, output=output)

    def comm_comp_breakdown(self, comm_matcher: Optional[Callable[[str], bool]] = None
                            ) -> EventFrame:
        self._ensure_structure()
        return ops_comm.comm_comp_breakdown(self, comm_matcher=comm_matcher)

    # ------------------------------------------------------------------
    # §IV-D performance-issue ops
    # ------------------------------------------------------------------
    def load_imbalance(self, metric: str = EXC, num_processes: int = 5,
                       top_functions: Optional[int] = None) -> EventFrame:
        self._ensure_structure()
        return ops_summary.load_imbalance(self, metric=metric,
                                          num_processes=num_processes,
                                          top_functions=top_functions)

    def idle_time(self, idle_functions: Sequence[str] = DEFAULT_IDLE_NAMES,
                  k: Optional[int] = None) -> EventFrame:
        self._ensure_structure()
        return ops_summary.idle_time(self, idle_functions=idle_functions, k=k)

    def detect_pattern(self, start_event: Optional[str] = None, **kw) -> List[EventFrame]:
        return ops_patterns.detect_pattern(self, start_event=start_event, **kw)

    def calculate_lateness(self) -> EventFrame:
        return ops_logical.calculate_lateness(self)

    def lateness_by_process(self) -> EventFrame:
        return ops_logical.lateness_by_process(self)

    def critical_path_analysis(self) -> List[EventFrame]:
        return ops_logical.critical_path_analysis(self)

    @staticmethod
    def multirun_analysis(traces: Sequence["Trace"], metric: str = EXC,
                          top_n: int = 16) -> EventFrame:
        for t in traces:
            t._ensure_structure()
        return ops_summary.multi_run_analysis(traces, metric=metric, top_n=top_n)

    # ------------------------------------------------------------------
    # §IV-E data reduction
    # ------------------------------------------------------------------
    def filter(self, f: Filter) -> "Trace":
        sub = self.events.mask(f.mask(self.events))
        out = Trace(self._strip_structure(sub), definitions=self.definitions,
                    label=self.label)
        return out

    def slice_time(self, start: float, end: float, trim: str = "overlap") -> "Trace":
        """Events whose call interval overlaps [start, end] (default), or whose
        own timestamp falls inside with trim="within"."""
        self._ensure_structure()
        ev = self.events
        ts = np.asarray(ev[TS], np.float64)
        if trim == "within":
            m = (ts >= start) & (ts <= end)
        else:
            mts = np.asarray(ev.column(MATCH_TS), np.float64)
            lo = np.fmin(ts, mts)
            hi = np.fmax(ts, mts)
            lo = np.where(np.isnan(lo), ts, lo)
            hi = np.where(np.isnan(hi), ts, hi)
            m = (hi >= start) & (lo <= end)
        return Trace(self._strip_structure(ev.mask(m)),
                     definitions=self.definitions, label=self.label)

    def filter_processes(self, procs: Sequence[int]) -> "Trace":
        m = np.isin(np.asarray(self.events[PROC], np.int64), np.asarray(list(procs)))
        return Trace(self._strip_structure(self.events.mask(m)),
                     definitions=self.definitions, label=self.label)

    @staticmethod
    def _strip_structure(ev: EventFrame) -> EventFrame:
        # row indices in derived columns are invalidated by row selection
        return ev.drop(MATCH, MATCH_TS, "_depth", PARENT, INC, EXC, "_cct_node")

    # ------------------------------------------------------------------
    # visualization (delegates; matplotlib optional)
    # ------------------------------------------------------------------
    def plot_timeline(self, **kw):
        from . import viz
        return viz.plot_timeline(self, **kw)

    def plot_time_profile(self, **kw):
        from . import viz
        return viz.plot_time_profile(self, **kw)

    def plot_comm_matrix(self, **kw):
        from . import viz
        return viz.plot_comm_matrix(self, **kw)

    def plot_comm_by_process(self, **kw):
        from . import viz
        return viz.plot_comm_by_process(self, **kw)

    def plot_message_histogram(self, **kw):
        from . import viz
        return viz.plot_message_histogram(self, **kw)
