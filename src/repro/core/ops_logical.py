"""Logical structure, lateness (Isaacs et al.) and critical-path analysis
(paper §IV-D, Figs. 10/11).

The *logical structure* assigns every communication operation a global step
index using the happens-before relation: within a process operations are
sequential; a receive happens after its matching send.  Physical timestamps
give a valid topological order (message latency is non-negative), so logical
steps are computed in one sweep over time-sorted operations.

``calculate_lateness``: lateness(op) = t_complete(op) − min over processes of
t_complete at the same logical step — how far an operation lags the fastest
peer at the same point of the logical program.

``critical_path_analysis``: backward walk from the last completion.  Within a
process we hop to the previous operation; when the walk reaches a receive
whose matching send *ends later than the previous local operation* (i.e. the
process was genuinely waiting on the message), it jumps to the sender.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from .constants import (ENTER, ET, INSTANT, LEAVE, MPI_RECV, MPI_SEND, NAME,
                        PROC, TS)
from .frame import EventFrame
from .registry import register_op

__all__ = ["logical_steps", "calculate_lateness", "critical_path_analysis"]


# -- recognizing communication operations ---------------------------------

_RECV_NAMES = ("MPI_Recv", "MPI_Irecv", "MPI_Wait", "MPI_Waitall", MPI_RECV, "recv")
_SEND_NAMES = ("MPI_Send", "MPI_Isend", MPI_SEND, "send")


def _op_rows(trace) -> np.ndarray:
    """Rows that constitute 'operations' for the logical timeline: Enter
    events of communication functions plus message instants."""
    ev = trace.events
    et = ev.cat(ET)
    name = ev.cat(NAME)
    is_comm = name.mask_isin(_RECV_NAMES + _SEND_NAMES)
    sel = is_comm & (et.mask_eq(ENTER) | et.mask_eq(INSTANT))
    return np.nonzero(sel)[0]


@register_op("logical_steps", needs_structure=True, needs_messages=True)
def logical_steps(trace) -> EventFrame:
    """Logical (happens-before) step per communication operation.

    Assigns every send/recv/wait operation a global step index: within a
    process operations are sequential, and a receive's step exceeds its
    matching send's — the logical timeline of Isaacs et al. that lateness
    and critical-path analysis build on.

    Returns:
        EventFrame with one row per operation: ``row`` (index into
        ``trace.events``), ``Process``, ``Name``, ``Timestamp (ns)``,
        ``complete`` (ns when the operation finished — its Leave, or its
        own timestamp for instants), and ``step`` (logical step index).
    """
    trace._ensure_structure()
    trace._ensure_messages()
    ev = trace.events
    ts = np.asarray(ev[TS], np.float64)
    procs = np.asarray(ev[PROC], np.int64)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    mmatch = trace._msg_match
    name = ev.cat(NAME)
    is_recv = name.mask_isin(_RECV_NAMES)

    rows = _op_rows(trace)
    if len(rows) == 0:
        return EventFrame({"row": np.asarray([], np.int64)})

    # completion time: Leave of the call (Enter rows) or own ts (instants)
    complete = np.where(match[rows] >= 0, ts[np.maximum(match[rows], 0)], ts[rows])

    # message partner *operation*: for a recv operation, the row of the send
    # operation it depends on.  Message instants are matched directly; for
    # Enter(MPI_Recv) style rows, the instant lives inside the call — map the
    # instant's row to its enclosing comm Enter via parent links.
    parent = np.asarray(ev.column("_parent"), np.int64)
    op_of_row = np.full(len(ev), -1, np.int64)
    op_of_row[rows] = np.arange(len(rows))
    # an instant's operation is itself if selected, else its parent Enter
    inst_rows = np.nonzero(ev.cat(ET).mask_eq(INSTANT))[0]
    carrier = np.where(op_of_row[inst_rows] >= 0, inst_rows,
                       np.maximum(parent[inst_rows], 0))

    pred = np.full(len(rows), -1, np.int64)  # op index of message predecessor
    if mmatch is not None:
        recv_inst = inst_rows[(mmatch[inst_rows] >= 0) & name.mask_eq(MPI_RECV)[inst_rows]]
        for r in recv_inst:
            send_row = mmatch[r]
            # send's carrying operation
            s_op = op_of_row[send_row]
            if s_op < 0 and parent[send_row] >= 0:
                s_op = op_of_row[parent[send_row]]
            r_op = op_of_row[r]
            if r_op < 0 and parent[r] >= 0:
                r_op = op_of_row[parent[r]]
            if r_op >= 0 and s_op >= 0:
                pred[r_op] = s_op

    # sweep in completion-time order; per-process step counters
    order = np.argsort(complete, kind="stable")
    step = np.zeros(len(rows), np.int64)
    nproc = int(procs.max()) + 1
    proc_step = np.full(nproc, -1, np.int64)
    op_proc = procs[rows]
    for i in order:
        s = proc_step[op_proc[i]] + 1
        if pred[i] >= 0:
            s = max(s, step[pred[i]] + 1)
        step[i] = s
        proc_step[op_proc[i]] = s

    return EventFrame({
        "row": rows, PROC: op_proc.astype(np.int32),
        NAME: ev.cat(NAME).take(rows),
        TS: ts[rows], "complete": complete, "step": step,
    })


@register_op("calculate_lateness", needs_structure=True, needs_messages=True)
def calculate_lateness(trace) -> EventFrame:
    """Lateness per communication operation (§IV-D, Isaacs et al. [27]).

    ``lateness(op) = complete(op) − min over processes of complete at the
    same logical step`` — how far (ns) an operation lags the fastest peer
    at the same point of the logical program.  0 marks the front-runner.

    Returns:
        The :func:`logical_steps` frame plus a ``lateness`` column (ns).
    """
    ops = logical_steps(trace)
    if len(ops) == 0:
        return ops
    step = np.asarray(ops["step"], np.int64)
    complete = np.asarray(ops["complete"], np.float64)
    nsteps = int(step.max()) + 1
    earliest = np.full(nsteps, np.inf)
    np.minimum.at(earliest, step, complete)
    out = ops.copy()
    out["lateness"] = complete - earliest[step]
    return out


@register_op("lateness_by_process", needs_structure=True, needs_messages=True)
def lateness_by_process(trace) -> EventFrame:
    """Maximum lateness per process (paper Fig. 11, right).

    Identifies the processes that fall furthest behind the logical front —
    the usual suspects for a load-imbalance or slow-link root cause.

    Returns:
        EventFrame with ``Process`` and ``max_lateness`` (ns, the worst
        lateness of any of the process's operations), sorted descending.
    """
    ops = calculate_lateness(trace)
    if len(ops) == 0:
        return ops
    procs = np.asarray(ops[PROC], np.int64)
    late = np.asarray(ops["lateness"], np.float64)
    nproc = int(procs.max()) + 1
    mx = np.zeros(nproc)
    np.maximum.at(mx, procs, late)
    order = np.argsort(-mx, kind="stable")
    return EventFrame({PROC: order.astype(np.int32), "max_lateness": mx[order]})


@register_op("critical_path_analysis", needs_structure=True, needs_messages=True)
def critical_path_analysis(trace, max_hops: int = 1_000_000) -> List[EventFrame]:
    """Critical path of the execution (§IV-D, Fig. 10).

    Walks backward from the last completion: within a process it hops to
    the previous operation; at a receive that was genuinely waiting (its
    matching send ends later than the previous local operation) it jumps to
    the sender.  The result is the dependency chain that bounds the run's
    wall-clock time — shorten something on it or the run doesn't speed up.

    Args:
        max_hops: safety bound on walk length for malformed traces.

    Returns:
        Single-element list (paper API shape) holding an EventFrame of the
        path's events, earliest first, with ``_row`` giving each event's
        row index in ``trace.events``.
    """
    trace._ensure_structure()
    trace._ensure_messages()
    ev = trace.events
    ts = np.asarray(ev[TS], np.float64)
    procs = np.asarray(ev[PROC], np.int64)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    parent = np.asarray(ev.column("_parent"), np.int64)
    mmatch = trace._msg_match
    name = ev.cat(NAME)
    et = ev.cat(ET)
    is_enter = et.mask_eq(ENTER)
    is_recv_call = name.mask_isin(_RECV_NAMES) & is_enter
    n = len(ev)
    if n == 0:
        return [EventFrame()]

    # per-process event rows in time order (enters only, the call timeline)
    ent_rows = np.nonzero(is_enter)[0]
    by_proc: dict = {}
    posmap = np.full(n, -1, np.int64)
    for p in np.unique(procs[ent_rows]):
        rows = ent_rows[procs[ent_rows] == p]
        rows = rows[np.argsort(ts[rows], kind="stable")]
        by_proc[int(p)] = rows
        posmap[rows] = np.arange(len(rows))

    # map recv call -> matching send call row (via the message instants)
    recv2send = np.full(n, -1, np.int64)
    if mmatch is not None:
        inst_rows = np.nonzero(name.mask_eq(MPI_RECV) & (mmatch >= 0))[0]
        for r in inst_rows:
            rcall = parent[r] if parent[r] >= 0 else r
            scall = parent[mmatch[r]] if parent[mmatch[r]] >= 0 else mmatch[r]
            if rcall >= 0:
                recv2send[rcall] = scall

    # start: the *last operation* (latest Enter) on the last-finishing process
    leaves = np.nonzero(et.mask_eq(LEAVE) & (match >= 0))[0]
    if len(leaves) == 0:
        return [EventFrame()]
    p_star = int(procs[leaves[np.argmax(ts[leaves])]])
    cur = int(by_proc[p_star][-1])
    path: List[int] = []
    hops = 0
    while cur >= 0 and hops < max_hops:
        hops += 1
        path.append(cur)
        p = int(procs[cur])
        rows = by_proc.get(p)
        i = int(posmap[cur])  # index of cur within its process timeline
        if is_recv_call[cur] and recv2send[cur] >= 0:
            prev_end = ts[match[rows[i - 1]]] if i > 0 and match[rows[i - 1]] >= 0 \
                else -np.inf
            send = int(recv2send[cur])
            send_end = ts[match[send]] if match[send] >= 0 else ts[send]
            if send_end >= prev_end:  # genuinely waiting on the message
                cur = send
                continue
        cur = int(rows[i - 1]) if i > 0 else -1
    path_rows = np.asarray(path[::-1], np.int64)
    out = ev.take(path_rows)
    out["_row"] = path_rows
    return [out]
