"""Rank-failure-tolerant live monitoring of an N-rank trace shard fleet.

A distributed job run under :class:`repro.runtime.tracer.Tracer` with a
sink produces one append-mode pack shard per rank plus a heartbeat file
(``rank_<r>.pack`` / ``rank_<r>.pack.hb``).  :class:`LiveTraceSet` is the
monitor side: it watches the shard directory, classifies each rank from
heartbeat age —

* **live**      heartbeat younger than ``lag_timeout`` (or a clean
  ``final`` heartbeat: the rank shut down after flushing everything),
* **lagging**   older than ``lag_timeout`` but younger than
  ``dead_timeout`` — a straggler, still included in queries,
* **dead**      older than ``dead_timeout`` (a SIGKILLed or hung rank) —
  excluded from queries, its committed prefix reported but not read,

— and executes **degraded-mode queries** over the survivors (live +
lagging), returning an explicit :class:`Coverage` report alongside every
result: which ranks contributed, each rank's committed watermark, and
the staleness spread (max − min committed ``ts_max`` across included
ranks), so "the answer is missing ranks 3 and 5 and rank 2 is 4 s
behind" is part of the result, never a silent omission.

Timeouts use an injectable ``clock`` (``time.time`` by default) so tests
can age ranks deterministically without sleeping.
"""

from __future__ import annotations

import glob
import os
import re
import time
from typing import Any, Dict, List, Optional, Tuple

from .streaming import DEFAULT_CHUNK_ROWS, LiveTrace, Watermark

__all__ = ["Coverage", "LiveTraceSet"]

_RANK_RE = re.compile(r"(\d+)")


def _rank_of(path: str, hb: Optional[dict], fallback: int) -> int:
    """Rank id for a shard: heartbeat field, else the first integer in
    the filename (``rank_3.pack`` → 3), else positional index."""
    if hb and isinstance(hb.get("rank"), int):
        return hb["rank"]
    m = _RANK_RE.search(os.path.basename(path))
    return int(m.group(1)) if m else fallback


class Coverage:
    """What a degraded-mode result actually covers.

    ``per_rank`` maps rank id → ``{status, path, rows, ts_max,
    heartbeat_age, finalized}`` for **every** discovered rank, dead ones
    included (their committed watermark is still reported — the data is
    durable even if the writer is gone).  ``staleness_spread`` is the
    max − min committed ``ts_max`` across included ranks (same clock
    domain as the tracer timestamps): how far the freshest included rank
    has run ahead of the stalest.  ``degraded`` is True whenever any
    discovered rank was excluded.
    """

    __slots__ = ("ranks_total", "included", "missing", "per_rank",
                 "staleness_spread", "degraded")

    def __init__(self, per_rank: Dict[int, dict]):
        self.per_rank = {r: dict(info) for r, info in per_rank.items()}
        self.ranks_total = len(self.per_rank)
        self.included = sorted(r for r, i in self.per_rank.items()
                               if i["status"] != "dead")
        self.missing = sorted(r for r, i in self.per_rank.items()
                              if i["status"] == "dead")
        ts = [self.per_rank[r]["ts_max"] for r in self.included
              if self.per_rank[r]["ts_max"] is not None]
        self.staleness_spread = (max(ts) - min(ts)) if len(ts) > 1 else 0
        self.degraded = bool(self.missing)

    def as_dict(self) -> dict:
        return {"ranks_total": self.ranks_total,
                "included": list(self.included),
                "missing": list(self.missing),
                "degraded": self.degraded,
                "staleness_spread": self.staleness_spread,
                "per_rank": {str(r): dict(i)
                             for r, i in sorted(self.per_rank.items())}}

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Coverage({len(self.included)}/{self.ranks_total} ranks"
                f"{', missing ' + str(self.missing) if self.missing else ''}"
                f", spread={self.staleness_spread})")


class LiveTraceSet:
    """Watch a directory of per-rank append shards; query the survivors.

    ``refresh()`` re-globs ``pattern`` under ``root``, reads each shard's
    heartbeat (falling back to the shard file's mtime when a rank never
    wrote one), classifies ranks live/lagging/dead, and rebuilds the
    underlying :class:`LiveTrace` when the survivor set changed (or just
    re-snapshots it when not).  ``run()`` refreshes, executes a terminal
    op over the survivors' committed prefixes, and returns ``(value,
    coverage, watermark)``.  Zero survivors raises — an all-dead fleet
    must not masquerade as an empty-but-healthy one.
    """

    def __init__(self, root: str, pattern: str = "rank_*.pack",
                 lag_timeout: float = 2.0, dead_timeout: float = 10.0,
                 chunk_rows: Optional[int] = None,
                 processes: Optional[int] = None, executor: str = "auto",
                 cache: bool = True, clock=time.time, **reader_kwargs):
        if dead_timeout < lag_timeout:
            raise ValueError("dead_timeout must be >= lag_timeout")
        self.root = os.fspath(root)
        self.pattern = pattern
        self.lag_timeout = float(lag_timeout)
        self.dead_timeout = float(dead_timeout)
        self.chunk_rows = chunk_rows
        self.processes = processes
        self.executor = executor
        self.cache = cache
        self.clock = clock
        self.reader_kwargs = dict(reader_kwargs)
        self._lt: Optional[LiveTrace] = None
        self._coverage: Optional[Coverage] = None
        self.refresh()

    # -- classification ------------------------------------------------------
    def _classify(self) -> Dict[int, dict]:
        from ..readers.pack import committed_prefix
        from ..runtime.tracer import read_heartbeat
        now = self.clock()
        per_rank: Dict[int, dict] = {}
        paths = sorted(glob.glob(os.path.join(self.root, self.pattern)))
        for idx, path in enumerate(paths):
            hb = read_heartbeat(path)
            if hb is not None and hb.get("wall") is not None:
                age = max(0.0, now - float(hb["wall"]))
            else:
                try:
                    age = max(0.0, now - os.stat(path).st_mtime)
                except OSError:
                    continue  # shard vanished between glob and stat
            wm = committed_prefix(path)["watermark"]
            if hb is not None and hb.get("final"):
                status = "live"      # clean shutdown: complete, not stale
            elif age <= self.lag_timeout:
                status = "live"
            elif age <= self.dead_timeout:
                status = "lagging"
            else:
                status = "dead"
            rank = _rank_of(path, hb, idx)
            per_rank[rank] = {
                "status": status, "path": path,
                "rows": wm["rows"], "ts_max": wm["ts_max"],
                "finalized": wm["finalized"],
                "heartbeat_age": round(age, 3),
            }
        return per_rank

    def refresh(self) -> Coverage:
        """Re-scan the fleet; returns the new :class:`Coverage`."""
        per_rank = self._classify()
        cov = Coverage(per_rank)
        survivor_paths = [per_rank[r]["path"] for r in cov.included]
        if self._lt is not None and list(self._lt.paths) == survivor_paths:
            self._lt.refresh()   # same fleet — just advance the snapshot
        elif survivor_paths:
            self._lt = LiveTrace(
                survivor_paths,
                chunk_rows=self.chunk_rows or DEFAULT_CHUNK_ROWS,
                processes=self.processes, executor=self.executor,
                cache=self.cache, label=os.path.basename(self.root),
                **self.reader_kwargs)
        else:
            self._lt = None
        self._coverage = cov
        return cov

    # -- introspection -------------------------------------------------------
    @property
    def coverage(self) -> Coverage:
        return self._coverage

    @property
    def watermark(self) -> Optional[Watermark]:
        """Combined watermark over the survivors (None when all dead)."""
        return self._lt.watermark if self._lt is not None else None

    def members(self) -> Dict[int, dict]:
        """Per-rank classification snapshot (rank → info dict)."""
        return {r: dict(i) for r, i in self._coverage.per_rank.items()}

    # -- execution -----------------------------------------------------------
    def trace(self) -> LiveTrace:
        """The survivor-spanning :class:`LiveTrace` handle as of the last
        refresh.  Raises when every rank is dead."""
        if self._lt is None:
            raise RuntimeError(
                f"no surviving ranks under {self.root!r} "
                f"(all {self._coverage.ranks_total} dead or none found) — "
                f"refusing to serve an empty result as healthy")
        return self._lt

    def run(self, op_name: str, *args: Any, **kwargs: Any
            ) -> Tuple[Any, Coverage, Watermark]:
        """Refresh, run a terminal op over the survivors' committed
        prefixes, return ``(value, coverage, watermark)``."""
        cov = self.refresh()
        lt = self.trace()
        res = lt.run_with_watermark(op_name, *args, **kwargs)
        return res.value, cov, res.watermark

    def query(self):
        """A lazy query over the survivors (no auto-refresh — pin first)."""
        return self.trace().query()

    def to_traceset(self):
        """Survivors as a :class:`~repro.core.diff.TraceSet` of per-rank
        live handles, labeled ``rank<r>`` — for cross-rank comparison ops
        (e.g. straggler diffs) over the committed prefixes."""
        from .diff import TraceSet
        cov = self._coverage
        members: List[LiveTrace] = []
        labels: List[str] = []
        for r in cov.included:
            members.append(LiveTrace(
                [cov.per_rank[r]["path"]],
                chunk_rows=self.chunk_rows or DEFAULT_CHUNK_ROWS,
                cache=self.cache, label=f"rank{r}", **self.reader_kwargs))
            labels.append(f"rank{r}")
        return TraceSet(members, labels=labels)

    def __repr__(self) -> str:  # pragma: no cover
        c = self._coverage
        return (f"LiveTraceSet({self.root!r}, {len(c.included)}/"
                f"{c.ranks_total} ranks live)")
