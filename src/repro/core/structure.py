"""Structural derivations over a trace: enter/leave matching, call depth,
caller/callee (parent) relations, inclusive/exclusive metrics, message matching.

All hot paths are vectorized NumPy (the paper's §III-A argument); the only
Python-level loops are over *call depth levels* (tens) and mismatch-repair
fallbacks, never over events.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .constants import (ENTER, ET, INSTANT, LEAVE, MPI_RECV, MPI_SEND, NAME,
                        PARTNER, PROC, TAG, THREAD, TS)
from .frame import EventFrame


def _group_ids(events: EventFrame) -> np.ndarray:
    """Integer id per (process, thread)."""
    proc = np.asarray(events[PROC], np.int64)
    if THREAD in events:
        thread = np.asarray(events[THREAD], np.int64)
    else:
        thread = np.zeros_like(proc)
    key = proc * (thread.max() + 1 if len(thread) else 1) + thread
    _, gid = np.unique(key, return_inverse=True)
    return gid.astype(np.int64)


def match_events(events: EventFrame) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized enter/leave matching.

    Returns ``(matching, depth, order)`` where ``matching[i]`` is the row index
    of event *i*'s partner (-1 for instants / unmatched), ``depth[i]`` is the
    call depth of the event (0 = top level), and ``order`` is the
    (process, thread, time)-sorted permutation used (stable; callers reuse it).

    Algorithm: within each (process, thread), Enter=+1 / Leave=-1 gives a
    running depth via segmented cumsum.  Within one (group, depth) level,
    enters and leaves strictly alternate in time order, so the k-th enter
    matches the k-th leave — a pure sort-and-align, no stack machine.
    """
    n = len(events)
    matching = np.full(n, -1, np.int64)
    depth = np.zeros(n, np.int32)
    if n == 0:
        return matching, depth, np.arange(0)

    gid = _group_ids(events)
    ts = np.asarray(events[TS], np.int64)
    et = events.cat(ET)
    is_enter = et.mask_eq(ENTER)
    is_leave = et.mask_eq(LEAVE)

    order = np.lexsort((ts, gid))  # stable: preserves file order for equal ts
    g_s = gid[order]
    sign = np.where(is_enter[order], 1, np.where(is_leave[order], -1, 0)).astype(np.int64)

    # segmented cumulative depth (reset at each group boundary)
    total = np.cumsum(sign)
    grp_start = np.zeros(n, dtype=bool)
    grp_start[0] = True
    grp_start[1:] = g_s[1:] != g_s[:-1]
    start_idx = np.nonzero(grp_start)[0]
    base_vals = np.concatenate([[0], total[start_idx[1:] - 1]])
    seg = np.cumsum(grp_start) - 1  # group ordinal per sorted row
    post = total - base_vals[seg]

    e_s = is_enter[order]
    l_s = is_leave[order]
    # depth of the call an event belongs to
    depth_call = np.where(e_s, post - 1, post).astype(np.int64)
    neg = depth_call < 0  # unbalanced leaves (truncated head) — unmatched
    depth_call = np.maximum(depth_call, 0)

    pos = np.arange(n, dtype=np.int64)
    # composite key (group, depth) — dense encoding
    maxd = int(depth_call.max()) + 1 if n else 1
    key = g_s * maxd + depth_call

    ew = np.nonzero(e_s & ~neg)[0]
    lw = np.nonzero(l_s & ~neg)[0]
    # sort each side by (key, position); stable lexsort keeps time order per key
    e_sorted = ew[np.lexsort((pos[ew], key[ew]))]
    l_sorted = lw[np.lexsort((pos[lw], key[lw]))]

    m = min(len(e_sorted), len(l_sorted))
    ok = np.zeros(m, dtype=bool)
    if m:
        ok = key[e_sorted[:m]] == key[l_sorted[:m]]
    if m and not ok.all() or len(e_sorted) != len(l_sorted):
        # unbalanced trace (e.g. truncated): repair by per-key alignment
        e_sorted, l_sorted = _align_by_key(key, pos, e_sorted, l_sorted)
        m = len(e_sorted)
        ok = np.ones(m, dtype=bool)
    e_al, l_al = e_sorted[:m][ok[:m]], l_sorted[:m][ok[:m]]
    # enter must precede its leave
    good = pos[e_al] < pos[l_al]
    e_al, l_al = e_al[good], l_al[good]

    orig_e = order[e_al]
    orig_l = order[l_al]
    matching[orig_e] = orig_l
    matching[orig_l] = orig_e
    depth[order] = depth_call.astype(np.int32)
    return matching, depth, order


def _align_by_key(key, pos, e_sorted, l_sorted):
    """Per-key alignment fallback for unbalanced traces (rare path)."""
    ek, lk = key[e_sorted], key[l_sorted]
    keys = np.unique(np.concatenate([ek, lk]))
    e_keep, l_keep = [], []
    for k in keys:
        es = e_sorted[ek == k]
        ls = l_sorted[lk == k]
        m = min(len(es), len(ls))
        e_keep.append(es[:m])
        l_keep.append(ls[:m])
    return (np.concatenate(e_keep) if e_keep else e_sorted[:0],
            np.concatenate(l_keep) if l_keep else l_sorted[:0])


def compute_parents(events: EventFrame, matching: np.ndarray, depth: np.ndarray,
                    order: np.ndarray) -> np.ndarray:
    """Parent (enclosing call's Enter row) per event; -1 at top level.

    Loop over depth *levels* only: parent of an event at depth d is the most
    recent Enter at depth d-1 within the same (process, thread) — one
    ``searchsorted`` per level.
    """
    n = len(events)
    parent = np.full(n, -1, np.int64)
    if n == 0:
        return parent
    gid = _group_ids(events)
    et = events.cat(ET)
    is_enter = et.mask_eq(ENTER)

    # position of each event in the canonical (group, time) order
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    # encode (group, rank) into one sortable key; rank < n so multiply by n+1
    gkey = gid.astype(np.int64) * (n + 1) + rank

    # events at "slot depth" d need the latest enter at depth d-1 before them.
    # enters have slot depth = depth; leaves/instants slot depth = depth + 1
    # (they live *inside* the call at their depth)... but leaves belong to the
    # call at `depth`, whose parent is at depth-1 — identical to their enter's
    # parent, so we assign leave parents from their matched enter afterwards.
    is_leave = et.mask_eq(LEAVE)
    inst = ~is_enter & ~is_leave

    maxd = int(depth.max()) if n else 0
    enters_by_depth = {}
    for d in range(0, maxd + 1):
        sel = np.nonzero(is_enter & (depth == d))[0]
        enters_by_depth[d] = sel[np.argsort(gkey[sel], kind="stable")]

    for d in range(1, maxd + 1):
        targets = np.nonzero((is_enter & (depth == d)) | (inst & (depth == d)))[0]
        if len(targets) == 0:
            continue
        cand = enters_by_depth.get(d - 1)
        if cand is None or len(cand) == 0:
            continue
        ck = gkey[cand]
        j = np.searchsorted(ck, gkey[targets]) - 1
        valid = j >= 0
        pj = cand[np.maximum(j, 0)]
        valid &= gid[pj] == gid[targets]
        parent[targets[valid]] = pj[valid]

    # instants at depth 0 sit inside the depth-0 call? no: depth 0 instant is
    # outside any call only if no call open; if inside the top-level call its
    # depth is 1 (post of cumsum unchanged by instant). Handled above.
    leaves = np.nonzero(is_leave & (matching >= 0))[0]
    parent[leaves] = parent[matching[leaves]]
    return parent


def compute_inc_exc(events: EventFrame, matching: np.ndarray, parent: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Inclusive / exclusive time per Enter row (NaN elsewhere)."""
    n = len(events)
    ts = np.asarray(events[TS], np.float64)
    et = events.cat(ET)
    is_enter = et.mask_eq(ENTER)
    inc = np.full(n, np.nan)
    exc = np.full(n, np.nan)
    ent = np.nonzero(is_enter & (matching >= 0))[0]
    inc[ent] = ts[matching[ent]] - ts[ent]
    child_sum = np.zeros(n)
    has_par = ent[parent[ent] >= 0]
    np.add.at(child_sum, parent[has_par], inc[has_par])
    exc[ent] = inc[ent] - child_sum[ent]
    return inc, exc


#: process-local call counter for :func:`derive_structure` — the test hook
#: proving that reopening a pack with a structure sidecar (or streaming it
#: chunk by chunk) never re-derives structure.  Monotonic; snapshot before /
#: compare after.
DERIVE_CALLS = 0


def derive_structure(events: EventFrame) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, np.ndarray,
                                                  np.ndarray]:
    """The full structural derivation in one call:
    ``(matching, depth, parent, inc, exc)``.

    Single source of truth for the match → parents → inc/exc pipeline —
    used by ``Trace._ensure_structure`` on whole traces and by the
    streaming engine's :class:`~repro.core.streaming.CallStitcher` on every
    chunk (whose within-chunk pairs it resolves with exactly this kernel,
    keeping chunked and in-memory results bit-identical).  Every call bumps
    :data:`DERIVE_CALLS` (pack-sidecar tests assert the skip).
    """
    global DERIVE_CALLS
    DERIVE_CALLS += 1
    matching, depth, order = match_events(events)
    parent = compute_parents(events, matching, depth, order)
    inc, exc = compute_inc_exc(events, matching, parent)
    return matching, depth, parent, inc, exc


def match_messages(events: EventFrame) -> np.ndarray:
    """FIFO-match MpiSend/MpiRecv instants by (src, dst, tag) channel order.

    Returns ``msg_match`` with the partner row index (-1 if unmatched).
    """
    n = len(events)
    out = np.full(n, -1, np.int64)
    if n == 0 or PARTNER not in events:
        return out
    name = events.cat(NAME)
    sends = np.nonzero(name.mask_eq(MPI_SEND))[0]
    recvs = np.nonzero(name.mask_eq(MPI_RECV))[0]
    if len(sends) == 0 or len(recvs) == 0:
        return out
    proc = np.asarray(events[PROC], np.int64)
    partner = np.asarray(events[PARTNER], np.int64)
    tag = np.asarray(events[TAG], np.int64) if TAG in events else np.zeros(n, np.int64)
    ts = np.asarray(events[TS], np.int64)

    nprocs = int(proc.max()) + 1
    ntags = int(tag.max()) + 2
    # channel key: (src, dst, tag)
    s_key = (proc[sends] * nprocs + partner[sends]) * ntags + tag[sends]
    r_key = (partner[recvs] * nprocs + proc[recvs]) * ntags + tag[recvs]

    s_ord = sends[np.lexsort((ts[sends], s_key))]
    r_ord = recvs[np.lexsort((ts[recvs], r_key))]
    sk = (proc[s_ord] * nprocs + partner[s_ord]) * ntags + tag[s_ord]
    rk = (partner[r_ord] * nprocs + proc[r_ord]) * ntags + tag[r_ord]
    m = min(len(s_ord), len(r_ord))
    if m and (len(s_ord) != len(r_ord) or not np.array_equal(sk[:m], rk[:m])):
        s_ord, r_ord = _align_by_key_simple(sk, rk, s_ord, r_ord)
        m = len(s_ord)
    s_al, r_al = s_ord[:m], r_ord[:m]
    out[s_al] = r_al
    out[r_al] = s_al
    return out


def _align_by_key_simple(sk, rk, s_ord, r_ord):
    keys = np.unique(np.concatenate([sk, rk]))
    s_keep, r_keep = [], []
    for k in keys:
        ss = s_ord[sk == k]
        rr = r_ord[rk == k]
        m = min(len(ss), len(rr))
        s_keep.append(ss[:m])
        r_keep.append(rr[:m])
    return (np.concatenate(s_keep) if s_keep else s_ord[:0],
            np.concatenate(r_keep) if r_keep else r_ord[:0])
