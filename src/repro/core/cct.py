"""Calling context tree (paper §III-C, §IV-A).

A single CCT is kept per Trace, aggregated over both time and processes —
the union of every per-process, per-instant CCT.  Nodes are identified by
(parent node, function name); construction is vectorized per *depth level*
(np.unique over (parent_cct_node, name_code) pairs), never per event.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .constants import ENTER, ET, EXC, INC, NAME, PROC
from .frame import EventFrame


class CCTNode:
    __slots__ = ("nid", "name", "parent", "children", "depth")

    def __init__(self, nid: int, name: str, parent: Optional["CCTNode"], depth: int):
        self.nid = nid
        self.name = name
        self.parent = parent
        self.children: List["CCTNode"] = []
        self.depth = depth

    def path(self) -> List[str]:
        node, out = self, []
        while node is not None and node.nid != 0:
            out.append(node.name)
            node = node.parent
        return out[::-1]

    def __repr__(self) -> str:  # pragma: no cover
        return f"CCTNode({self.nid}, {'->'.join(self.path()) or '<root>'})"


class CCT:
    """Unified calling context tree + per-node aggregate metrics."""

    def __init__(self):
        self.root = CCTNode(0, "<root>", None, -1)
        self.nodes: List[CCTNode] = [self.root]
        # event row -> node id (filled by build); -1 for non-enter rows
        self.event_node: np.ndarray = np.asarray([], np.int64)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, events: EventFrame, parent: np.ndarray, depth: np.ndarray) -> "CCT":
        """Build the union CCT from per-event parent links.

        ``parent[i]`` is the row index of the enclosing call's Enter (-1 at
        top level); only Enter rows spawn nodes.  Work is O(levels) passes of
        vectorized unique/gather.
        """
        cct = cls()
        n = len(events)
        cct.event_node = np.full(n, -1, np.int64)
        if n == 0:
            return cct
        is_enter = events.cat(ET).mask_eq(ENTER)
        name_codes = events.codes(NAME)
        cats = events.cat(NAME).categories

        maxd = int(depth.max()) if n else 0
        # node id per event, built level by level
        for d in range(maxd + 1):
            rows = np.nonzero(is_enter & (depth == d))[0]
            if len(rows) == 0:
                continue
            if d == 0:
                par_nid = np.zeros(len(rows), np.int64)  # root
            else:
                par_rows = parent[rows]
                ok = par_rows >= 0
                par_nid = np.where(ok, cct.event_node[np.maximum(par_rows, 0)], 0)
            key = par_nid * (len(cats) + 1) + name_codes[rows]
            uniq, inv = np.unique(key, return_inverse=True)
            base = len(cct.nodes)
            for k in uniq:
                pn = int(k) // (len(cats) + 1)
                nc = int(k) % (len(cats) + 1)
                node = CCTNode(len(cct.nodes), str(cats[nc]), cct.nodes[pn], d)
                cct.nodes[pn].children.append(node)
                cct.nodes.append(node)
            cct.event_node[rows] = base + inv
        return cct

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def aggregate(self, events: EventFrame, metric: str = INC) -> EventFrame:
        """Per-node totals of ``metric`` (summed over time and processes)."""
        vals = np.nan_to_num(np.asarray(events.column(metric), np.float64))
        tot = np.zeros(len(self.nodes))
        sel = self.event_node >= 0
        np.add.at(tot, self.event_node[sel], vals[sel])
        names = np.asarray([" -> ".join(nd.path()) for nd in self.nodes], dtype=object)
        order = np.argsort(-tot, kind="stable")
        order = order[tot[order] > 0]
        return EventFrame({"path": names[order], metric: tot[order],
                           "node": order.astype(np.int64)})

    def per_process(self, events: EventFrame, node_id: int, metric: str = INC
                    ) -> EventFrame:
        """Metric for one call path, broken out by process — the paper's
        'same call path across different processes' discrepancy analysis."""
        sel = np.nonzero(self.event_node == node_id)[0]
        sub = events.take(sel)
        vals = np.nan_to_num(np.asarray(sub.column(metric), np.float64))
        procs = np.asarray(sub[PROC], np.int64)
        npr = int(procs.max()) + 1 if len(procs) else 0
        tot = np.zeros(npr)
        np.add.at(tot, procs, vals)
        return EventFrame({PROC: np.arange(npr, dtype=np.int32), metric: tot})

    def render(self, events: Optional[EventFrame] = None, metric: str = INC,
               max_nodes: int = 40) -> str:
        """ASCII rendering of the tree (depth-first), optionally with metrics."""
        tot = None
        if events is not None and metric in events:
            vals = np.nan_to_num(np.asarray(events.column(metric), np.float64))
            tot = np.zeros(len(self.nodes))
            sel = self.event_node >= 0
            np.add.at(tot, self.event_node[sel], vals[sel])
        lines: List[str] = []

        def rec(node: CCTNode, prefix: str):
            if len(lines) >= max_nodes:
                return
            label = node.name
            if tot is not None and node.nid != 0:
                label += f"  [{tot[node.nid]:.4g}]"
            lines.append(prefix + label)
            for ch in node.children:
                rec(ch, prefix + "  ")

        rec(self.root, "")
        if len(self.nodes) > max_nodes:
            lines.append(f"... ({len(self.nodes)} nodes)")
        return "\n".join(lines)
