"""Summary/aggregation operations (paper §IV-B, §IV-D in part).

All functions take a Trace whose structure columns (matching, parent,
time.inc/time.exc) are already materialized; Trace methods guarantee that.

Each op with a combinable partial-aggregate form also registers a streaming
aggregator (``register_streaming``) so the out-of-core executor
(:mod:`repro.core.streaming`) can run it chunk by chunk over traces that do
not fit in RAM; the aggregators reproduce the in-memory results (exactly,
for integer-ns traces — see docs/streaming.md).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from . import accel
from .constants import (DEFAULT_IDLE_NAMES, ENTER, ET, EXC, INC, NAME, PROC, TS)
from .frame import Categorical, EventFrame
from .registry import (get_backend, op_backends, register_backend,
                       register_op, register_streaming)
from .streaming import StreamAgg, StreamingUnsupported, grow_to


# ---------------------------------------------------------------------------
# time_profile backends (the prototype of the per-op backend registry)
# ---------------------------------------------------------------------------

#: the live ``time_profile`` backend table — an alias of
#: ``registry.op_backends("time_profile")`` kept for backwards
#: compatibility (mutating it *is* registration).  A backend maps call
#: records onto the [bins, functions] overlap matrix:
#: ``fn(starts, ends, rate, name_codes, edges, nf) -> np.ndarray``
#: with ``starts``/``ends`` float64 ns, ``rate`` weight/ns, ``name_codes``
#: int codes < nf, ``edges`` the bin edge array (len num_bins+1).
TIME_PROFILE_BACKENDS: Dict[str, Callable[..., np.ndarray]] = \
    op_backends("time_profile")


def register_time_profile_backend(name: str) -> Callable:
    """Decorator registering a ``time_profile(backend=<name>)`` accumulation
    backend (last registration wins, like the op registry).  Equivalent to
    ``registry.register_backend("time_profile", name)``."""
    return register_backend("time_profile", name)


@register_op("flat_profile", needs_structure=True)
def flat_profile(trace, metrics: Sequence[str] = (EXC,), groupby_column: str = NAME,
                 per_process: bool = False, backend: str = "numpy") -> EventFrame:
    """Total metric per function, aggregated over the whole trace (§IV-B).

    Sums each metric over every *matched call* (Enter event) of a function,
    across all processes unless ``per_process``.

    Args:
        metrics: metric columns to sum — ``time.exc`` (default; ns the
            function spent in its own code, callees excluded) and/or
            ``time.inc`` (ns including callees; inclusive sums over nested
            calls of the same function double-count by design).
        groupby_column: grouping key (default ``Name``; any categorical
            column works, e.g. a custom phase column).
        per_process: additionally group by ``Process`` (one row per
            (function, process) pair).
        backend: ``"numpy"`` (default, exact) or ``"pallas"`` (one-hot
            matmul segment-sum kernel, f32 rounding; see docs/kernels.md).

    Returns:
        EventFrame with the group key column(s), one summed column per
        metric (ns), and ``count`` (number of calls), sorted by the first
        metric descending.
    """
    return get_backend("flat_profile", backend)(
        trace, metrics=metrics, groupby_column=groupby_column,
        per_process=per_process)


@register_backend("flat_profile", "numpy")
def _flat_profile_numpy(trace, *, metrics: Sequence[str] = (EXC,),
                        groupby_column: str = NAME,
                        per_process: bool = False) -> EventFrame:
    """The exact reference: one groupby over every Enter row."""
    ev = trace.events
    ent = ev.mask(ev.cat(ET).mask_eq(ENTER))
    keys = [groupby_column, PROC] if per_process else [groupby_column]
    aggs = {m: "sum" for m in metrics}
    prof = ent.groupby_agg(keys, aggs, count_name="count")
    # NaN-safe: unmatched enters carry NaN metrics
    for m in metrics:
        prof[m] = np.nan_to_num(prof[m])
    order = np.argsort(-prof[metrics[0]], kind="stable")
    return prof.take(order)


def _flat_assemble(names_alpha, counts, sums, metrics, per_process
                   ) -> EventFrame:
    """Shared finalization of the record-level flat_profile paths: counts
    (exact int64) and per-metric sums, both on the alphabetical name axis,
    become the output frame.  Used by the streaming aggregator and the
    pallas backend on every path — identical assembly is half of the
    digest-identity contract."""
    out = EventFrame()
    if per_process:
        f_alpha, p_alpha = np.nonzero(counts)
        out[NAME] = Categorical(f_alpha.astype(np.int32), names_alpha)
        out[PROC] = p_alpha.astype(np.int64)
        out["count"] = counts[f_alpha, p_alpha]
        for i, m in enumerate(metrics):
            out[m] = sums[i, f_alpha, p_alpha]
    else:
        present = np.nonzero(counts)[0]
        out[NAME] = Categorical(present.astype(np.int32), names_alpha)
        out["count"] = counts[present]
        for i, m in enumerate(metrics):
            out[m] = sums[i, present]
    order = np.argsort(-np.asarray(out[metrics[0]]), kind="stable")
    return out.take(order)


@register_backend("flat_profile", "pallas")
def _flat_profile_pallas(trace, *, metrics: Sequence[str] = (EXC,),
                         groupby_column: str = NAME,
                         per_process: bool = False) -> EventFrame:
    """Accelerator flat profile: canonical-ordered completed-call records
    through the seg_sum / pair_sum one-hot-matmul kernels.  Counts stay
    exact (host int64); metric sums agree with numpy to f32 rounding."""
    if groupby_column != NAME:
        raise ValueError(
            f"flat_profile backend='pallas' groups by {NAME!r} only, got "
            f"groupby_column={groupby_column!r}; use backend='numpy'")
    metrics = list(metrics)
    ev = trace.events
    is_enter = ev.cat(ET).mask_eq(ENTER)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    ts = np.asarray(ev[TS], np.float64)
    codes = ev.codes(NAME)
    procs = np.asarray(ev[PROC], np.int64)
    names_alpha, _order, inv = accel.alpha_positions(ev.cat(NAME).categories)
    nf = len(names_alpha)
    nprocs = max(trace.num_processes, 1)

    ent = np.nonzero(is_enter)[0]
    acode_all = inv[codes[ent]]
    if per_process:
        counts = np.zeros((nf, nprocs), np.int64)
        np.add.at(counts, (acode_all, procs[ent]), 1)
    else:
        counts = np.bincount(acode_all, minlength=nf).astype(np.int64)

    # kernel records: matched calls only (unmatched enters contribute
    # exactly 0 to the numpy sums; the NaN-poisoning they cause is applied
    # per metric below, mirroring nan_to_num-after-groupby)
    msel = np.nonzero(is_enter & (match >= 0))[0]
    vals = np.stack([np.nan_to_num(
        np.asarray(ev.column(m), np.float64)[msel]) for m in metrics],
        axis=1)
    acode = inv[codes[msel]]
    pr = procs[msel]
    o = accel.canonical_order(ts[msel], ts[match[msel]], pr, acode,
                              vals[:, 0])
    if per_process:
        sums = np.stack([accel.pair_sum(acode[o], pr[o], vals[o, i],
                                        nf, nprocs)
                         for i in range(len(metrics))])
    else:
        sums = accel.seg_sum(acode[o], vals[o], nf).T
    for i, m in enumerate(metrics):
        bad = np.isnan(np.asarray(ev.column(m), np.float64)[ent])
        if bad.any():
            if per_process:
                sums[i][acode_all[bad], procs[ent][bad]] = 0.0
            else:
                sums[i][acode_all[bad]] = 0.0
    return _flat_assemble(names_alpha, counts, sums, metrics, per_process)


@register_op("time_profile", needs_structure=True)
def time_profile(trace, num_bins: int = 32, metric: str = EXC,
                 normalized: bool = False, backend: str = "numpy") -> EventFrame:
    """Flat profile over time (§IV-B): bins × functions matrix.

    Each matched call contributes its metric, modeled as uniformly spread
    over its [enter, leave) span; the trace's [t_min, t_max] is divided
    into ``num_bins`` equal bins.  Exact O(N + bins·functions) NumPy sweep
    (no N×bins matrix); ``backend="pallas"`` routes the dense tiled kernel
    in repro.kernels.time_bin (TPU target; interpret-mode on CPU).

    Args:
        num_bins: number of equal-width time bins.
        metric: ``time.exc`` (default) or ``time.inc``, in ns.
        normalized: scale each bin's values to fractions of that bin's
            total (rows sum to 1 where any time was recorded).
        backend: a backend registered in :data:`TIME_PROFILE_BACKENDS`
            (the live ``registry.op_backends("time_profile")`` table) —
            built-ins are ``"numpy"`` (exact sweep) and ``"pallas"``
            (tiled kernel); register your own with
            :func:`register_time_profile_backend`.  Non-numpy backends
            run on canonically ordered call records, so every execution
            path (eager, streaming, parallel, pack) produces an
            identical frame.

    Returns:
        EventFrame with ``bin_start``/``bin_end`` (ns) plus one column per
        function holding its per-bin metric (ns, or fractions when
        ``normalized``), columns ordered by total weight descending.
    """
    ev = trace.events
    ts = np.asarray(ev[TS], np.float64)
    if len(ev) == 0:
        return EventFrame({"bin_start": np.asarray([]), "bin_end": np.asarray([])})
    t0, t1 = float(ts.min()), float(ts.max())
    if t1 <= t0:
        t1 = t0 + 1.0
    edges = np.linspace(t0, t1, num_bins + 1)

    is_enter = ev.cat(ET).mask_eq(ENTER)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    sel = np.nonzero(is_enter & (match >= 0))[0]
    starts = ts[sel]
    ends = ts[match[sel]]
    w = np.nan_to_num(np.asarray(ev.column(metric), np.float64)[sel])
    name_codes = ev.codes(NAME)[sel]
    cats = ev.cat(NAME).categories
    nf = len(cats)

    fn = get_backend("time_profile", backend)
    if backend != "numpy":
        # record-level path shared with the streaming finalizer: canonical
        # order + alphabetical code space ⇒ identical frames on every path
        names_alpha, _order, inv = accel.alpha_positions(cats)
        procs = np.asarray(ev[PROC], np.int64)[sel]
        return _profile_from_records(starts, ends, w, procs,
                                     inv[name_codes], names_alpha, edges,
                                     num_bins, normalized, fn)

    inc = ends - starts
    rate = np.where(inc > 0, w / np.maximum(inc, 1e-30), 0.0)
    prof = fn(starts, ends, rate, name_codes, edges, nf)

    # zero-duration calls: all weight in their bin
    zsel = inc <= 0
    if np.any(zsel & (w > 0)):
        b = np.clip(np.searchsorted(edges, starts[zsel], side="right") - 1, 0, num_bins - 1)
        np.add.at(prof, (b, name_codes[zsel]), w[zsel])

    if normalized:
        denom = prof.sum(axis=1, keepdims=True)
        prof = prof / np.maximum(denom, 1e-30)
    out = EventFrame({"bin_start": edges[:-1], "bin_end": edges[1:]})
    keep = np.nonzero(prof.sum(axis=0) > 0)[0]
    order = keep[np.argsort(-prof[:, keep].sum(axis=0), kind="stable")]
    for f in order:
        out[str(cats[f])] = prof[:, f]
    return out


def _profile_from_records(starts, ends, w, procs, acodes, names_alpha,
                          edges, num_bins, normalized, fn) -> EventFrame:
    """Record-level ``time_profile`` core for non-numpy backends, shared by
    the eager op and the streaming finalizer: canonical-sort the call
    records, invoke the backend once, apply the zero-duration fixup and
    assemble columns in the alphabetical code space.  Both paths hold the
    same record multiset, so the resulting frames are identical."""
    o = accel.canonical_order(starts, ends, procs, acodes, w)
    starts, ends, w, acodes = starts[o], ends[o], w[o], acodes[o]
    inc = ends - starts
    rate = np.where(inc > 0, w / np.maximum(inc, 1e-30), 0.0)
    prof = np.asarray(fn(starts, ends, rate, acodes, edges,
                         len(names_alpha)), np.float64)
    zsel = inc <= 0
    if np.any(zsel & (w > 0)):
        b = np.clip(np.searchsorted(edges, starts[zsel], side="right") - 1,
                    0, num_bins - 1)
        np.add.at(prof, (b, acodes[zsel]), w[zsel])
    if normalized:
        denom = prof.sum(axis=1, keepdims=True)
        prof = prof / np.maximum(denom, 1e-30)
    out = EventFrame({"bin_start": edges[:-1], "bin_end": edges[1:]})
    keep = np.nonzero(prof.sum(axis=0) > 0)[0]
    order = keep[np.argsort(-prof[:, keep].sum(axis=0), kind="stable")]
    for f in order:
        out[str(names_alpha[f])] = prof[:, f]
    return out


@register_time_profile_backend("pallas")
def _pallas_profile(starts, ends, rate, name_codes, edges, nf) -> np.ndarray:
    """The Pallas TPU kernel (repro.kernels.time_bin): scatter-free one-hot
    matmul accumulation, interpret-mode on CPU.  Values agree with the
    exact sweep to f32 rounding."""
    from ..kernels.ops import time_profile_matrix
    num_bins = len(edges) - 1
    t0, t1 = float(edges[0]), float(edges[-1])
    # normalize to bin units: f32 kernel arithmetic loses ns-scale
    # precision at bin boundaries otherwise
    bw = (t1 - t0) / num_bins
    if not (bw > 0) or not np.isfinite(bw):
        # degenerate span (all edges equal, e.g. a single-instant trace fed
        # directly): every overlap is zero — dividing by bw would turn that
        # into NaN where the numpy backend returns zeros
        return np.zeros((num_bins, nf))
    return np.asarray(time_profile_matrix(
        (starts - t0) / bw, (ends - t0) / bw, name_codes, rate * bw,
        n_funcs=nf, n_bins=num_bins, t0=0.0, t1=float(num_bins),
        be=accel.block_size(len(starts)))).T


@register_time_profile_backend("numpy")
def _exact_profile(starts, ends, rate, name_codes, edges, nf) -> np.ndarray:
    """C(t) = Σ rate_i·clamp(t−s_i, 0, e_i−s_i) evaluated at edges, per name.

    Decomposed into five cumulative histograms so cost is O(N + bins·names):
      C(t) = t·(P−Q) − (Ps−Qs) + R
    with P=Σr·1[s≤t], Q=Σr·1[e≤t], Ps=Σr·s·1[s≤t], Qs=Σr·s·1[e≤t],
    R=Σr·(e−s)·1[e≤t].
    """
    nb = len(edges) - 1
    # index of first edge >= value  →  contributes to cumulative at that edge on
    si = np.searchsorted(edges, starts, side="left")
    ei = np.searchsorted(edges, ends, side="left")
    H = np.zeros((5, nb + 2, nf))
    np.add.at(H[0], (si, name_codes), rate)                    # P
    np.add.at(H[1], (ei, name_codes), rate)                    # Q
    np.add.at(H[2], (si, name_codes), rate * starts)           # Ps
    np.add.at(H[3], (ei, name_codes), rate * starts)           # Qs
    np.add.at(H[4], (ei, name_codes), rate * (ends - starts))  # R
    cum = np.cumsum(H[:, : nb + 1, :], axis=1)  # value at each edge
    t = edges[:, None]
    C = t * (cum[0] - cum[1]) - (cum[2] - cum[3]) + cum[4]
    return np.maximum(np.diff(C, axis=0), 0.0)


@register_op("load_imbalance", needs_structure=True)
def load_imbalance(trace, metric: str = EXC, num_processes: int = 5,
                   top_functions: Optional[int] = None,
                   backend: str = "numpy") -> EventFrame:
    """Per-function load imbalance across processes (§IV-D, Fig. 7).

    For each function, sums the metric per process and reports
    max-over-processes / mean-over-processes — 1.0 is perfectly balanced,
    2.0 means the busiest process carries twice the average.

    Args:
        metric: ``time.exc`` (default) or ``time.inc``, in ns.
        num_processes: how many of the busiest process ids to list per
            function (does not affect the ratio).
        top_functions: truncate to the N functions with the largest mean
            metric (None = all functions with any time).
        backend: ``"numpy"`` (default, exact) or ``"pallas"`` (pair_sum
            one-hot matmul kernel, f32 rounding; see docs/kernels.md).

    Returns:
        EventFrame sorted by mean metric descending with ``Name``,
        ``<metric>.imbalance`` (the max/mean ratio), ``Top processes``
        (list of the heaviest process ids), ``<metric>.mean`` and
        ``<metric>.max`` (ns).
    """
    return get_backend("load_imbalance", backend)(
        trace, metric=metric, num_processes=num_processes,
        top_functions=top_functions)


def _imbalance_assemble(tot, names_alpha, metric, num_processes,
                        top_functions, nprocs) -> EventFrame:
    """Shared finalization of load_imbalance: the per-(function, process)
    totals matrix (name-code-aligned with ``names_alpha``) becomes the
    ranked imbalance frame — one implementation for the eager backends and
    the streaming finalizer."""
    nf = tot.shape[0]
    active = tot.sum(axis=1) > 0
    mean = tot.sum(axis=1) / max(nprocs, 1)
    mx = tot.max(axis=1) if tot.size else np.zeros(nf)
    imb = np.where(mean > 0, mx / np.maximum(mean, 1e-30), 0.0)
    topk = np.argsort(-tot, axis=1)[:, :num_processes]
    sel = np.nonzero(active)[0]
    order = sel[np.argsort(-mean[sel], kind="stable")]
    if top_functions:
        order = order[:top_functions]
    return EventFrame({
        NAME: Categorical(order.astype(np.int32), names_alpha),
        f"{metric}.imbalance": imb[order],
        "Top processes": np.asarray([list(map(int, topk[i])) for i in order], dtype=object),
        f"{metric}.mean": mean[order],
        f"{metric}.max": mx[order],
    })


@register_backend("load_imbalance", "numpy")
def _load_imbalance_numpy(trace, *, metric: str = EXC,
                          num_processes: int = 5,
                          top_functions: Optional[int] = None) -> EventFrame:
    """The exact reference: one scatter-add over every Enter row."""
    ev = trace.events
    ent = ev.mask(ev.cat(ET).mask_eq(ENTER))
    vals = np.nan_to_num(np.asarray(ent.column(metric), np.float64))
    names = ent.codes(NAME)
    procs = np.asarray(ent[PROC], np.int64)
    cats = ent.cat(NAME).categories
    nprocs = trace.num_processes
    nf = len(cats)
    tot = np.zeros((nf, nprocs))
    np.add.at(tot, (names, procs), vals)
    return _imbalance_assemble(tot, cats, metric, num_processes,
                               top_functions, nprocs)


@register_backend("load_imbalance", "pallas")
def _load_imbalance_pallas(trace, *, metric: str = EXC,
                           num_processes: int = 5,
                           top_functions: Optional[int] = None
                           ) -> EventFrame:
    """Accelerator load imbalance: canonical-ordered completed-call records
    through the pair_sum one-hot-matmul kernel (function × rank totals to
    f32 rounding; unmatched enters contribute exactly 0 in the reference
    and are simply dropped here)."""
    ev = trace.events
    ts = np.asarray(ev[TS], np.float64)
    is_enter = ev.cat(ET).mask_eq(ENTER)
    match = np.asarray(ev.column("_matching_event"), np.int64)
    sel = np.nonzero(is_enter & (match >= 0))[0]
    vals = np.nan_to_num(np.asarray(ev.column(metric), np.float64)[sel])
    names_alpha, _order, inv = accel.alpha_positions(ev.cat(NAME).categories)
    acode = inv[ev.codes(NAME)[sel]]
    procs = np.asarray(ev[PROC], np.int64)[sel]
    nprocs = trace.num_processes
    o = accel.canonical_order(ts[sel], ts[match[sel]], procs, acode, vals)
    tot = accel.pair_sum(acode[o], procs[o], vals[o], len(names_alpha),
                         max(nprocs, 1))
    return _imbalance_assemble(tot, names_alpha, metric, num_processes,
                               top_functions, nprocs)


@register_op("idle_time", needs_structure=True)
def idle_time(trace, idle_functions: Sequence[str] = DEFAULT_IDLE_NAMES,
              k: Optional[int] = None) -> EventFrame:
    """Total idle (wait/recv) time per process (§IV-D), sorted descending.

    Sums the *inclusive* time (ns) of every call whose name is in
    ``idle_functions`` — inclusive, because the whole span of an MPI_Wait
    counts as idle regardless of what bookkeeping runs inside it.

    Args:
        idle_functions: names treated as idleness (default: MPI_Wait,
            MPI_Waitall, MPI_Recv, Idle, MPI_Barrier).
        k: keep only the k most-idle processes (None = all).

    Returns:
        EventFrame with ``Process`` and ``idle_time`` (ns), most idle first.
    """
    ev = trace.events
    ent_mask = ev.cat(ET).mask_eq(ENTER) & ev.cat(NAME).mask_isin(idle_functions)
    ent = ev.mask(ent_mask)
    nprocs = trace.num_processes
    out = np.zeros(nprocs)
    np.add.at(out, np.asarray(ent[PROC], np.int64),
              np.nan_to_num(np.asarray(ent.column(INC), np.float64)))
    order = np.argsort(-out, kind="stable")
    res = EventFrame({PROC: order.astype(np.int32), "idle_time": out[order]})
    return res.head(k) if k else res


# ---------------------------------------------------------------------------
# streaming (out-of-core) forms — combinable partial aggregates per chunk
# ---------------------------------------------------------------------------

_CALL_METRICS = (INC, EXC)


def _check_metric(metric: str, op: str) -> None:
    if metric not in _CALL_METRICS:
        raise StreamingUnsupported(
            f"streaming {op} supports metrics {_CALL_METRICS}, got "
            f"{metric!r}; materialize with .collect() for custom metrics")


def _alpha(ctx, nf: int):
    """(sorted names, gather order, code→alphabetical-position map) over the
    first ``nf`` global codes — restores the category-code group order the
    in-memory groupby produces.  ``arr[order]`` re-orders a code-indexed
    axis alphabetically; ``inv[code]`` is a code's alphabetical position."""
    names = np.asarray(ctx.names.names[:nf], dtype=object).astype(str)
    order = np.argsort(names, kind="stable")
    inv = np.empty(nf, np.int64)
    inv[order] = np.arange(nf)
    return names[order], order, inv


def _pad_to(arr: np.ndarray, shape) -> np.ndarray:
    """Zero-padded copy of ``arr`` with exactly ``shape`` (accumulators may
    be under-grown when late chunks discovered names but produced no calls,
    and over-grown by the power-of-two capacity)."""
    out = np.zeros(shape, dtype=arr.dtype)
    sub = arr[tuple(slice(0, min(a, s)) for a, s in zip(arr.shape, shape))]
    out[tuple(slice(0, n) for n in sub.shape)] = sub
    return out


def _scatter_names(dst: np.ndarray, src: np.ndarray, code_map: np.ndarray,
                   axis: int) -> np.ndarray:
    """Add ``src`` (a worker accumulator whose ``axis`` is indexed by the
    worker's local name codes) into ``dst`` with that axis remapped through
    ``code_map`` — the shared kernel of every cross-worker ``merge_from``.
    ``src`` is padded to exactly ``len(code_map)`` names (and ``dst``'s
    extents on the other axes); ``dst`` is grown to hold the remapped codes.
    ``code_map`` entries are unique, so a fancy-indexed ``+=`` is exact.
    """
    k = len(code_map)
    if k == 0:
        return dst
    want = list(dst.shape)
    for ax in range(dst.ndim):
        if ax == axis:
            want[ax] = k
        else:
            want[ax] = max(want[ax], src.shape[ax] if ax < src.ndim else 0)
    src = _pad_to(src, tuple(want))
    grown = list(src.shape)
    grown[axis] = int(code_map.max()) + 1
    dst = grow_to(dst, tuple(grown))
    idx = [slice(0, n) for n in src.shape]
    idx[axis] = code_map
    dst[tuple(idx)] += src
    return dst


@register_streaming("flat_profile")
class _FlatProfileAgg(StreamAgg):
    """Combinable flat profile: per-name (or per name×process) metric sums
    over completed calls plus call counts over every Enter row.  Sums of
    integer-ns metrics are exact in float64 (< 2⁵³), so merging partials is
    order-independent and the result matches the in-memory op bit for bit.
    A name with an unmatched Enter reproduces the in-memory NaN-poisoning:
    its group total collapses to 0 (``nan_to_num`` after aggregation).

    ``backend="pallas"`` buffers the completed-call records instead of
    accumulating sums, then canonical-sorts and invokes the kernel once at
    finalize — exactly what the eager pallas backend does, so the two paths
    produce byte-identical frames (counts stay exact either way)."""

    needs_calls = True
    supports_parallel = True

    def __init__(self, metrics: Sequence[str] = (EXC,),
                 groupby_column: str = NAME, per_process: bool = False,
                 backend: str = "numpy"):
        if groupby_column != NAME:
            raise StreamingUnsupported(
                f"streaming flat_profile groups by {NAME!r} only, got "
                f"groupby_column={groupby_column!r}")
        get_backend("flat_profile", backend)  # fail fast on unknown names
        if backend not in ("numpy", "pallas"):
            raise StreamingUnsupported(
                f"streaming flat_profile supports backends ('numpy', "
                f"'pallas'); {backend!r} is trace-level — materialize with "
                f".collect() to use it")
        self.backend = backend
        self.metrics = list(metrics)
        for m in self.metrics:
            _check_metric(m, "flat_profile")
        self.per_process = per_process
        nm = len(self.metrics)
        self._recs: List[tuple] = []
        if per_process:
            self._counts = np.zeros((0, 0), np.int64)
            self._sums = np.zeros((nm, 0, 0))
        else:
            self._counts = np.zeros(0, np.int64)
            self._sums = np.zeros((nm, 0))

    def update(self, chunk) -> None:
        ev = chunk.events
        is_enter = ev.cat(ET).mask_eq(ENTER)
        codes = chunk.gcodes[is_enter]
        calls = chunk.calls
        nf = len(chunk.names)
        metric_vals = {INC: calls.inc, EXC: calls.exc}
        if self.backend != "numpy":
            vals = np.stack([np.nan_to_num(metric_vals[m])
                             for m in self.metrics], axis=1) \
                if len(calls.name) else np.zeros((0, len(self.metrics)))
            self._recs.append((calls.name.copy(), calls.proc.copy(),
                               calls.start.copy(), calls.end.copy(), vals))
        if self.per_process:
            procs = np.asarray(ev[PROC], np.int64)[is_enter]
            np_ = int(max(procs.max() + 1 if len(procs) else 0,
                          calls.proc.max() + 1 if len(calls.proc) else 0))
            self._counts = grow_to(self._counts, (nf, np_))
            np.add.at(self._counts, (codes, procs), 1)
            if self.backend == "numpy":
                self._sums = grow_to(self._sums,
                                     (self._sums.shape[0], nf, np_))
                for i, m in enumerate(self.metrics):
                    np.add.at(self._sums[i], (calls.name, calls.proc),
                              metric_vals[m])
        else:
            self._counts = grow_to(self._counts, (nf,))
            np.add.at(self._counts, codes, 1)
            if self.backend == "numpy":
                self._sums = grow_to(self._sums, (self._sums.shape[0], nf))
                for i, m in enumerate(self.metrics):
                    np.add.at(self._sums[i], calls.name, metric_vals[m])

    def merge_from(self, other, code_map) -> None:
        # counts/sums lead with the name axis in both layouts; procs (when
        # present) are global ids and need no remap
        self._counts = _scatter_names(self._counts, other._counts, code_map,
                                      axis=0)
        if self.backend == "numpy":
            self._sums = _scatter_names(self._sums, other._sums, code_map,
                                        axis=1)
        else:
            for name, proc, start, end, vals in other._recs:
                self._recs.append((code_map[name], proc, start, end, vals))

    def _gather_records(self, inv):
        """Concatenate the buffered call records into flat arrays with
        alphabetical name positions — shared by the pallas finalizers."""
        if self._recs:
            name = np.concatenate([r[0] for r in self._recs])
            proc = np.concatenate([r[1] for r in self._recs])
            start = np.concatenate([r[2] for r in self._recs])
            end = np.concatenate([r[3] for r in self._recs])
            vals = np.concatenate([r[4] for r in self._recs])
        else:
            name = proc = np.zeros(0, np.int64)
            start = end = np.zeros(0)
            vals = np.zeros((0, len(self.metrics)))
        return inv[name], proc, start, end, vals

    def result(self, ctx) -> EventFrame:
        nf = len(ctx.names)
        if self.backend == "numpy" and (nf == 0 or not np.any(self._counts)):
            out = EventFrame()
            out[NAME] = np.asarray([])
            for m in self.metrics:
                out[m] = np.asarray([])
            return out
        names_alpha, order, inv = _alpha(ctx, nf)
        open_names, open_procs = ctx.open_calls
        nm = len(self.metrics)
        if self.per_process:
            np_ = max(self._counts.shape[1], self._sums.shape[2],
                      ctx.num_processes, 1)
            counts = _pad_to(self._counts, (nf, np_))[order]
            if self.backend == "numpy":
                sums = _pad_to(self._sums, (nm, nf, np_))[:, order]
            else:
                acode, proc, start, end, vals = self._gather_records(inv)
                o = accel.canonical_order(start, end, proc, acode,
                                          vals[:, 0] if nm else start)
                sums = np.stack([accel.pair_sum(acode[o], proc[o],
                                                vals[o, i], nf, np_)
                                 for i in range(nm)]) \
                    if nm else np.zeros((0, nf, np_))
            if len(open_names):
                sums[:, inv[open_names], open_procs] = 0.0
        else:
            counts = _pad_to(self._counts, (nf,))[order]
            if self.backend == "numpy":
                sums = _pad_to(self._sums, (nm, nf))[:, order]
            else:
                acode, proc, start, end, vals = self._gather_records(inv)
                o = accel.canonical_order(start, end, proc, acode,
                                          vals[:, 0] if nm else start)
                sums = accel.seg_sum(acode[o], vals[o], nf).T
            if len(open_names):
                sums[:, inv[open_names]] = 0.0
        return _flat_assemble(names_alpha, counts, sums, self.metrics,
                              self.per_process)


@register_streaming("time_profile")
class _TimeProfileAgg(StreamAgg):
    """Combinable time profile: the exact five-histogram decomposition of
    the in-memory op, accumulated per chunk over completed calls.  A stats
    pre-pass fixes the global [t_min, t_max] bin edges first (the stream is
    read twice; peak memory stays bounded).  Partial-sum order differs from
    the in-memory single pass, so values agree to float64 rounding, not
    necessarily bit-for-bit.

    Non-numpy backends (record-level contract) buffer the completed-call
    records and run :func:`_profile_from_records` at finalize — the same
    canonical-sort + single-kernel-call core the eager op uses, so e.g.
    ``backend="pallas"`` yields byte-identical frames on both paths."""

    needs_calls = True
    needs_stats = True
    supports_parallel = True

    def __init__(self, num_bins: int = 32, metric: str = EXC,
                 normalized: bool = False, backend: str = "numpy"):
        _check_metric(metric, "time_profile")
        self._fn = get_backend("time_profile", backend)
        self.backend = backend
        self.num_bins = num_bins
        self.metric = metric
        self.normalized = normalized
        self._recs: List[tuple] = []
        self._H = np.zeros((5, num_bins + 2, 0))
        self._Z = np.zeros((num_bins, 0))
        self._edges: Optional[np.ndarray] = None

    def begin(self, stats) -> None:
        if stats.n_events == 0:
            return
        t0, t1 = stats.ts_min, stats.ts_max
        if t1 <= t0:
            t1 = t0 + 1.0
        self._edges = np.linspace(t0, t1, self.num_bins + 1)

    def update(self, chunk) -> None:
        calls = chunk.calls
        if calls is None or len(calls.name) == 0:
            return
        if self.backend != "numpy":
            w = np.nan_to_num(calls.inc if self.metric == INC else calls.exc)
            self._recs.append((calls.name.copy(), calls.proc.copy(),
                               calls.start.copy(), calls.end.copy(), w))
            return
        nf = len(chunk.names)
        self._H = grow_to(self._H, (5, self.num_bins + 2, nf))
        self._Z = grow_to(self._Z, (self.num_bins, nf))
        starts, ends = calls.start, calls.end
        inc = ends - starts
        w = np.nan_to_num(calls.inc if self.metric == INC else calls.exc)
        rate = np.where(inc > 0, w / np.maximum(inc, 1e-30), 0.0)
        codes = calls.name
        si = np.searchsorted(self._edges, starts, side="left")
        ei = np.searchsorted(self._edges, ends, side="left")
        np.add.at(self._H[0], (si, codes), rate)
        np.add.at(self._H[1], (ei, codes), rate)
        np.add.at(self._H[2], (si, codes), rate * starts)
        np.add.at(self._H[3], (ei, codes), rate * starts)
        np.add.at(self._H[4], (ei, codes), rate * (ends - starts))
        zsel = inc <= 0
        if np.any(zsel & (w > 0)):
            b = np.clip(np.searchsorted(self._edges, starts[zsel],
                                        side="right") - 1,
                        0, self.num_bins - 1)
            np.add.at(self._Z, (b, codes[zsel]), w[zsel])

    def merge_from(self, other, code_map) -> None:
        # bin edges come from the shared stats pre-pass, so workers and
        # parent agree on them; only the name axis needs remapping
        if self.backend != "numpy":
            for name, proc, start, end, w in other._recs:
                self._recs.append((code_map[name], proc, start, end, w))
            return
        self._H = _scatter_names(self._H, other._H, code_map, axis=2)
        self._Z = _scatter_names(self._Z, other._Z, code_map, axis=1)

    def result(self, ctx) -> EventFrame:
        if self._edges is None:
            return EventFrame({"bin_start": np.asarray([]),
                               "bin_end": np.asarray([])})
        nf = len(ctx.names)
        if self.backend != "numpy":
            names_alpha, _order, inv = _alpha(ctx, nf)
            if self._recs:
                name = np.concatenate([r[0] for r in self._recs])
                proc = np.concatenate([r[1] for r in self._recs])
                start = np.concatenate([r[2] for r in self._recs])
                end = np.concatenate([r[3] for r in self._recs])
                w = np.concatenate([r[4] for r in self._recs])
            else:
                name = proc = np.zeros(0, np.int64)
                start = end = w = np.zeros(0)
            return _profile_from_records(start, end, w, proc, inv[name],
                                         names_alpha, self._edges,
                                         self.num_bins, self.normalized,
                                         self._fn)
        H = _pad_to(self._H, (5, self.num_bins + 2, nf))
        Z = _pad_to(self._Z, (self.num_bins, nf))
        cum = np.cumsum(H[:, : self.num_bins + 1, :], axis=1)
        t = self._edges[:, None]
        C = t * (cum[0] - cum[1]) - (cum[2] - cum[3]) + cum[4]
        prof = np.maximum(np.diff(C, axis=0), 0.0) + Z
        names_alpha, order, _inv = _alpha(ctx, nf)
        prof = prof[:, order]
        if self.normalized:
            denom = prof.sum(axis=1, keepdims=True)
            prof = prof / np.maximum(denom, 1e-30)
        out = EventFrame({"bin_start": self._edges[:-1],
                          "bin_end": self._edges[1:]})
        keep = np.nonzero(prof.sum(axis=0) > 0)[0]
        order = keep[np.argsort(-prof[:, keep].sum(axis=0), kind="stable")]
        for f in order:
            out[str(names_alpha[f])] = prof[:, f]
        return out


@register_streaming("load_imbalance")
class _LoadImbalanceAgg(StreamAgg):
    """Combinable load imbalance: the per-(function, process) metric totals
    merge exactly across chunks (integer-ns sums); the ratio arithmetic at
    finalize is identical to the in-memory op.  ``backend="pallas"``
    buffers records and runs the pair_sum kernel once at finalize, exactly
    like the eager pallas backend."""

    needs_calls = True
    supports_parallel = True

    def __init__(self, metric: str = EXC, num_processes: int = 5,
                 top_functions: Optional[int] = None,
                 backend: str = "numpy"):
        _check_metric(metric, "load_imbalance")
        get_backend("load_imbalance", backend)
        if backend not in ("numpy", "pallas"):
            raise StreamingUnsupported(
                f"streaming load_imbalance supports backends ('numpy', "
                f"'pallas'); {backend!r} is trace-level — materialize with "
                f".collect() to use it")
        self.backend = backend
        self.metric = metric
        self.num_processes = num_processes
        self.top_functions = top_functions
        self._recs: List[tuple] = []
        self._tot = np.zeros((0, 0))

    def update(self, chunk) -> None:
        calls = chunk.calls
        if calls is None or len(calls.name) == 0:
            return
        vals = calls.inc if self.metric == INC else calls.exc
        if self.backend != "numpy":
            self._recs.append((calls.name.copy(), calls.proc.copy(),
                               calls.start.copy(), calls.end.copy(),
                               np.nan_to_num(vals)))
            return
        nf = len(chunk.names)
        np_ = int(calls.proc.max()) + 1
        self._tot = grow_to(self._tot, (nf, np_))
        np.add.at(self._tot, (calls.name, calls.proc), vals)

    def merge_from(self, other, code_map) -> None:
        if self.backend != "numpy":
            for name, proc, start, end, vals in other._recs:
                self._recs.append((code_map[name], proc, start, end, vals))
            return
        self._tot = _scatter_names(self._tot, other._tot, code_map, axis=0)

    def result(self, ctx) -> EventFrame:
        nf = len(ctx.names)
        nprocs = ctx.num_processes
        names_alpha, order, inv = _alpha(ctx, nf)
        if self.backend == "numpy":
            tot = _pad_to(self._tot, (nf, max(nprocs, 1)))[order]
        else:
            if self._recs:
                name = np.concatenate([r[0] for r in self._recs])
                proc = np.concatenate([r[1] for r in self._recs])
                start = np.concatenate([r[2] for r in self._recs])
                end = np.concatenate([r[3] for r in self._recs])
                vals = np.concatenate([r[4] for r in self._recs])
            else:
                name = proc = np.zeros(0, np.int64)
                start = end = vals = np.zeros(0)
            acode = inv[name]
            o = accel.canonical_order(start, end, proc, acode, vals)
            tot = accel.pair_sum(acode[o], proc[o], vals[o], nf,
                                 max(nprocs, 1))
        return _imbalance_assemble(tot, names_alpha, self.metric,
                                   self.num_processes, self.top_functions,
                                   nprocs)


@register_streaming("idle_time")
class _IdleTimeAgg(StreamAgg):
    """Combinable idle time: per-process inclusive-ns sums of idle-named
    completed calls — exact merge for integer-ns traces."""

    needs_calls = True
    supports_parallel = True

    def __init__(self, idle_functions: Sequence[str] = DEFAULT_IDLE_NAMES,
                 k: Optional[int] = None):
        self.idle = [str(n) for n in idle_functions]
        self.k = k
        self._out = np.zeros(0)

    def update(self, chunk) -> None:
        calls = chunk.calls
        if calls is None or len(calls.name) == 0:
            return
        idle_codes = [c for c in
                      (chunk.names.code(n) for n in self.idle)
                      if c >= 0]
        if not idle_codes:
            return
        sel = np.isin(calls.name, np.asarray(idle_codes, np.int64))
        if not np.any(sel):
            return
        np_ = int(calls.proc[sel].max()) + 1
        self._out = grow_to(self._out, (np_,))
        np.add.at(self._out, calls.proc[sel], np.nan_to_num(calls.inc[sel]))

    def merge_from(self, other, code_map) -> None:
        # keyed by process only (idle-name matching already happened in the
        # worker's own code space); plain padded add
        self._out = grow_to(self._out, other._out.shape)
        self._out[: len(other._out)] += other._out

    def result(self, ctx) -> EventFrame:
        nprocs = ctx.num_processes
        out = np.zeros(max(nprocs, 0))
        sub = self._out[:nprocs]
        out[: len(sub)] = sub
        order = np.argsort(-out, kind="stable")
        res = EventFrame({PROC: order.astype(np.int32),
                          "idle_time": out[order]})
        return res.head(self.k) if self.k else res


def multi_run_analysis(traces: Sequence, metric: str = EXC, top_n: int = 16,
                       label_column: str = "Run") -> EventFrame:
    """Joined flat profiles across runs (§IV-D, Fig. 12).

    Thin wrapper over the TraceDiff alignment machinery
    (:func:`repro.core.diff.align_flat_profiles`): one row per run, one
    column per function in the union of each run's top-``top_n`` functions
    by ``metric`` (columns ordered by total weight across runs).  For
    deltas, scaling series, or regression flags use the set-scoped ops in
    :mod:`repro.core.diff` directly.
    """
    from .diff import align_flat_profiles
    labels, cols, mat, _present = align_flat_profiles(traces, metric=metric,
                                                      top_n=top_n)
    out = EventFrame({label_column: np.asarray(labels, dtype=object)})
    for j, c in enumerate(cols):
        out[c] = mat[:, j]
    return out
