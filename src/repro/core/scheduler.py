"""Shared execution scheduler: one object owns every worker pool.

Before this module each :class:`~repro.core.streaming.StreamingTrace`
lazily created its *own* spawn pool on the first parallel terminal op, and
a :class:`~repro.core.diff.TraceSet` stitched its members to one pool by
hand.  That is fine for a single script, but a long-lived trace-query
service (:mod:`repro.serving.tracequery`) holds *many* handles across many
client sessions — per-handle pools would multiply worker startup cost
(interpreter + NumPy import per worker) and oversubscribe the machine by
the number of open sessions.

The :class:`Scheduler` centralizes pool ownership:

* :meth:`spawn_pool` — the multiprocessing spawn pools the parallel plan
  executor (:mod:`repro.core.executor`) fans work units into.  One pool
  per distinct worker count, created on first use, shared by every handle
  (library scripts and service sessions alike) and kept alive for the
  scheduler's lifetime, so worker startup is paid once per process — not
  once per handle.
* :meth:`lane` — two bounded thread pools ("interactive" / "bulk") the
  service uses as admission-control lanes: interactive small-window
  queries run on reserved threads that a 10M-event full scan can never
  occupy.  Library code is free to use them too (they are plain
  ``concurrent.futures`` executors).

``get_scheduler()`` returns the process-wide default; tests and embedders
can swap it with ``set_scheduler()``.  Handles can still carry an explicit
pool (``StreamingTrace._pool``) — the scheduler is the *default* owner,
not a mandate.
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

from ..parallel_util import SharedPool, resolve_processes

__all__ = ["Scheduler", "get_scheduler", "set_scheduler"]


class Scheduler:
    """Process-wide owner of spawn pools and the two service thread lanes.

    ``workers`` bounds the *total* thread-lane budget (default: CPU
    count); ``interactive_workers`` of those are reserved for the
    interactive lane (default: a quarter, at least 1).  Spawn pools are
    sized by their callers (the parallel executor resolves the handle's
    ``processes=``) and deduplicated by size.
    """

    def __init__(self, workers: Optional[int] = None,
                 interactive_workers: Optional[int] = None):
        self.workers = resolve_processes(workers)
        if interactive_workers is None:
            interactive_workers = max(1, self.workers // 4)
        self.interactive_workers = max(1, min(int(interactive_workers),
                                              self.workers))
        self.bulk_workers = max(1, self.workers - self.interactive_workers)
        self._lock = threading.Lock()
        self._spawn_pools: Dict[int, SharedPool] = {}
        self._lanes: Dict[str, ThreadPoolExecutor] = {}
        self._closed = False

    # -- multiprocessing spawn pools (parallel plan executor) -------------
    def spawn_pool(self, processes: Optional[int] = None) -> SharedPool:
        """The shared spawn pool for ``processes`` workers (None = one per
        core).  Pools are created lazily and cached by size, so two handles
        opened with ``processes=4`` fan into the same four workers."""
        n = resolve_processes(processes)
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is shut down")
            pool = self._spawn_pools.get(n)
            if pool is None:
                pool = self._spawn_pools[n] = SharedPool(n)
            return pool

    # -- thread lanes (service admission control) -------------------------
    def lane(self, name: str) -> ThreadPoolExecutor:
        """The ``"interactive"`` or ``"bulk"`` thread lane.  Interactive
        threads are reserved: bulk work is never scheduled onto them, which
        is what keeps small-window queries responsive under a full scan."""
        if name not in ("interactive", "bulk"):
            raise ValueError(f'lane must be "interactive" or "bulk", '
                             f'got {name!r}')
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is shut down")
            ex = self._lanes.get(name)
            if ex is None:
                n = (self.interactive_workers if name == "interactive"
                     else self.bulk_workers)
                ex = self._lanes[name] = ThreadPoolExecutor(
                    max_workers=n, thread_name_prefix=f"tracequery-{name}")
            return ex

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {"workers": self.workers,
                    "interactive_workers": self.interactive_workers,
                    "bulk_workers": self.bulk_workers,
                    "spawn_pools": sorted(self._spawn_pools),
                    "lanes": sorted(self._lanes)}

    # -- teardown ----------------------------------------------------------
    def shutdown(self, wait: bool = True) -> None:
        """Close every pool and lane.  Idempotent; a shut-down scheduler
        refuses to hand out new pools."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pools = list(self._spawn_pools.values())
            lanes = list(self._lanes.values())
            self._spawn_pools.clear()
            self._lanes.clear()
        for ex in lanes:
            ex.shutdown(wait=wait)
        for pool in pools:
            pool.close()


_DEFAULT: Optional[Scheduler] = None
_DEFAULT_LOCK = threading.Lock()


def get_scheduler() -> Scheduler:
    """The process-wide default scheduler (created on first use)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = Scheduler()
        return _DEFAULT


def set_scheduler(scheduler: Optional[Scheduler]) -> Optional[Scheduler]:
    """Swap the default scheduler; returns the previous one (tests restore
    it).  ``None`` resets to lazy re-creation on next use."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        prev, _DEFAULT = _DEFAULT, scheduler
        return prev
