"""Lazy, composable query plans over traces (paper §IV-E, §VII).

The eager ``Trace`` methods materialize a sub-frame per call and throw away
all derived structure (enter/leave matching, parents, depth, inc/exc), so a
chain like ``trace.filter(a).slice_time(x, y).filter_processes(...)`` pays N
full-column copies and re-runs the matching machinery on the next analysis
op.  ``TraceQuery`` instead records the chain as a small logical plan and
executes it on the first terminal op:

* **mask fusion** — consecutive row-selection steps evaluate to boolean
  masks on the *same* frame and are AND-ed into one mask applied once per
  column, so an N-step chain materializes one sub-frame, not N;
* **structure reuse** — when a selection keeps enter/leave pairs and parent
  chains intact (process subsets, whole-call-interval windows), the derived
  index columns are *remapped* through the old→new row map instead of being
  recomputed (no lexsorts); inclusive/exclusive metrics are recomputed with
  the same O(N) kernel the eager path uses, so results are bit-identical.
  When pairs are actually broken the plan falls back to a full recompute;
* **predicate pushdown** — plans built over on-disk shards
  (:func:`scan`) extract the process restriction of the whole chain via
  ``Filter.process_bounds()`` and hand it to the parallel reader, which
  skips shards before parsing;
* **op registry** — every §IV analysis op is a terminal method on the query
  (resolved through :mod:`repro.core.registry`), and its declared
  prerequisites (structure / message matching) are materialized exactly once
  per plan.

Example::

    (trace.query()
          .slice_time(t0, t1)                 # call-interval window
          .filter(Filter("Name", "not-in", ["MPI_Wait"]))
          .restrict_processes(range(8))
          .flat_profile())                    # plan executes here
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from . import registry, structure
from .constants import (CCT_NODE, DERIVED_COLUMNS, ENTER, ET, EXC, INC,
                        LEAVE, MATCH, MATCH_TS, MPI_RECV, MPI_SEND, NAME,
                        PARENT, PROC, TS)
from .filters import Filter, _And, _Not, _Or
from .frame import EventFrame

__all__ = ["TraceQuery", "scan"]


# ---------------------------------------------------------------------------
# plan steps
# ---------------------------------------------------------------------------

class Step:
    """One row-selection step of a logical plan."""

    def needs_structure(self) -> bool:
        """True when this step's mask reads matching timestamps (overlap
        windows).  Such a step can still fuse past pair-preserving pending
        selections, which keep per-row (ts, match_ts) intact."""
        return False

    def reads_derived(self) -> bool:
        """True when this step's mask reads derived *value* columns
        (inc/exc/depth/parent/...), whose contents change with the selection
        itself — forcing an unconditional materialization barrier so the
        predicate sees the same recomputed values the eager chain sees."""
        return False

    def mask(self, trace) -> np.ndarray:
        raise NotImplementedError

    def proc_hint(self):
        """(bounds, explicit_set) restriction this step puts on Process."""
        return None, None

    def describe(self) -> str:
        raise NotImplementedError


class FilterStep(Step):
    """A plain row predicate.  Overlap-trimmed time windows never reach this
    step type — _decompose_filter turns them into SliceTimeStep."""

    def __init__(self, f: Filter):
        self.filter = f

    def reads_derived(self) -> bool:
        return bool(self.filter.columns() & set(DERIVED_COLUMNS))

    def mask(self, trace) -> np.ndarray:
        return np.asarray(self.filter.mask(trace.events), bool)

    def proc_hint(self):
        return self.filter.process_bounds(), None

    def describe(self) -> str:
        return f"filter {self.filter!r}"


class SliceTimeStep(Step):
    def __init__(self, start: float, end: float, trim: str = "overlap"):
        if trim not in ("overlap", "within"):
            raise ValueError(f'trim must be "overlap" or "within", got {trim!r}')
        self.start, self.end, self.trim = start, end, trim

    def needs_structure(self) -> bool:
        return self.trim == "overlap"

    def mask(self, trace) -> np.ndarray:
        ts = np.asarray(trace.events[TS], np.float64)
        if self.trim == "within":
            return (ts >= self.start) & (ts <= self.end)
        return _overlap_mask(trace, self.start, self.end)

    def describe(self) -> str:
        return f"slice_time [{self.start:g}, {self.end:g}] trim={self.trim}"


class ProcessStep(Step):
    def __init__(self, procs: Sequence[int]):
        self.procs = np.unique(np.asarray(list(procs), np.int64))

    def mask(self, trace) -> np.ndarray:
        return np.isin(np.asarray(trace.events[PROC], np.int64), self.procs)

    def proc_hint(self):
        return None, frozenset(int(p) for p in self.procs)

    def describe(self) -> str:
        return f"restrict_processes {list(map(int, self.procs))}"


def _overlap_mask(trace, start: float, end: float) -> np.ndarray:
    """Events whose call interval [min(ts, match_ts), max(...)] overlaps the
    window — identical arithmetic to the eager Trace.slice_time."""
    ev = trace.events
    ts = np.asarray(ev[TS], np.float64)
    mts = np.asarray(ev.column(MATCH_TS), np.float64)
    lo = np.fmin(ts, mts)
    hi = np.fmax(ts, mts)
    lo = np.where(np.isnan(lo), ts, lo)
    hi = np.where(np.isnan(hi), ts, hi)
    return (hi >= start) & (lo <= end)


# ---------------------------------------------------------------------------
# selection execution: fused mask apply + structure remap
# ---------------------------------------------------------------------------

def _strip(ev: EventFrame) -> EventFrame:
    return ev.drop(*DERIVED_COLUMNS)


def _remap_safe(keep: np.ndarray, match: np.ndarray, parent: np.ndarray,
                is_call: np.ndarray) -> bool:
    """True when the selection provably preserves derived structure:

    * no kept Enter/Leave is unmatched (unbalanced traces always recompute),
    * every kept event's matching partner is kept (pairs intact),
    * every kept event's parent is kept (so, transitively, dropped events
      form whole subtrees and recomputed depth/parents equal the originals).
    """
    has_m = match >= 0
    if np.any(keep & is_call & ~has_m):
        return False
    km = keep & has_m
    if not np.all(keep[match[km]]):
        return False
    kp = keep & (parent >= 0)
    if not np.all(keep[parent[kp]]):
        return False
    return True


def _remap_messages(trace, keep: np.ndarray, new_index: np.ndarray
                    ) -> Optional[np.ndarray]:
    """Remap the cached send/recv matching, or None when FIFO re-matching on
    the sub-frame could pair differently (partner dropped, or unmatched
    message instants survive the selection)."""
    mm = trace._msg_match
    if mm is None:
        return None
    has = mm >= 0
    if not np.all(keep[mm[keep & has]]):
        return None  # a kept message's partner is dropped
    name = trace.events.cat(NAME)
    msgish = name.mask_eq(MPI_SEND) | name.mask_eq(MPI_RECV)
    if np.any(keep & msgish & ~has):
        return None  # surviving unmatched instants could re-pair
    old = mm[keep]
    return np.where(old >= 0, new_index[np.maximum(old, 0)], -1)


def apply_selection(trace, keep: np.ndarray):
    """Materialize ``trace`` restricted to ``keep`` rows.

    When the parent trace carries structure and the selection preserves it
    (see :func:`_remap_safe`), the matching/parent index columns are remapped
    through the old→new row map and inc/exc are recomputed with the same
    O(N) kernel the from-scratch path uses — bit-identical results without
    any lexsort.  Otherwise derived columns are dropped and recomputed
    lazily, exactly like the eager path.
    """
    keep = np.asarray(keep, bool)
    ev = trace.events
    cls = type(trace)
    structured = trace._structured and MATCH in ev and PARENT in ev
    if not structured:
        out = cls(_strip(ev.mask(keep)), definitions=trace.definitions,
                  label=trace.label)
        return out

    match = np.asarray(ev.column(MATCH), np.int64)
    parent = np.asarray(ev.column(PARENT), np.int64)
    et = ev.cat(ET)
    is_call = et.mask_eq(ENTER) | et.mask_eq(LEAVE)
    if not _remap_safe(keep, match, parent, is_call):
        out = cls(_strip(ev.mask(keep)), definitions=trace.definitions,
                  label=trace.label)
        return out

    idx = np.nonzero(keep)[0]
    new_index = np.full(len(keep), -1, np.int64)
    new_index[idx] = np.arange(len(idx))
    # drop every column we rebuild below before the take — no wasted gathers
    sub = ev.drop(CCT_NODE, MATCH, PARENT, INC, EXC, MATCH_TS).mask(keep)
    old_m, old_p = match[idx], parent[idx]
    sub[MATCH] = np.where(old_m >= 0, new_index[np.maximum(old_m, 0)], -1)
    sub[PARENT] = np.where(old_p >= 0, new_index[np.maximum(old_p, 0)], -1)
    new_match = np.asarray(sub.column(MATCH), np.int64)
    new_parent = np.asarray(sub.column(PARENT), np.int64)
    # exclusive metrics of boundary calls change when a subtree is dropped —
    # recompute with the canonical kernel (linear, no sort) for bit-identity
    inc, exc = structure.compute_inc_exc(sub, new_match, new_parent)
    sub[INC] = inc
    sub[EXC] = exc
    ts = np.asarray(sub[TS], np.float64)
    sub[MATCH_TS] = np.where(new_match >= 0, ts[np.maximum(new_match, 0)],
                             np.nan)
    out = cls(sub, definitions=trace.definitions, label=trace.label)
    out._structured = True
    out._msg_match = _remap_messages(trace, keep, new_index)
    return out


def _has_overlap_leaf(f: Filter) -> bool:
    if isinstance(f, (_And, _Or)):
        return _has_overlap_leaf(f.a) or _has_overlap_leaf(f.b)
    if isinstance(f, _Not):
        return _has_overlap_leaf(f.a)
    return f.trim == "overlap"


def _split_windows(f: Filter):
    """(window steps, residual filter or None) for a conjunction tree."""
    if isinstance(f, _And):
        w1, r1 = _split_windows(f.a)
        w2, r2 = _split_windows(f.b)
        if r1 is None:
            residual = r2
        elif r2 is None:
            residual = r1
        else:
            residual = _And(r1, r2)
        return w1 + w2, residual
    if f.trim == "overlap":
        start, end = f.window()
        return [SliceTimeStep(start, end, "overlap")], None
    if _has_overlap_leaf(f):
        raise ValueError(
            "a time_window_filter(trim='overlap') cannot appear under '|' or "
            "'~'; compose it with '&' or chain .slice_time() on the query")
    return [], f


def _decompose_filter(f: Filter) -> List[Step]:
    """Split one filter into plan steps so overlap-trimmed time windows keep
    their call-interval semantics inside conjunctions.

    Windows are hoisted in front; everything else in the conjunction stays
    *one* FilterStep whose conjuncts evaluate against a single frame — like
    the seed's ``_And.mask`` — so ``a & b`` and ``b & a`` are identical even
    when a conjunct reads derived columns.  An overlap window under ``|`` or
    ``~`` has no well-defined row semantics and is rejected loudly rather
    than silently degrading to timestamp-within.
    """
    windows, residual = _split_windows(f)
    steps: List[Step] = list(windows)
    if residual is not None:
        steps.append(FilterStep(residual))
    return steps


def _fully_matched(trace) -> bool:
    """True when every Enter/Leave in the (structured) frame has a partner —
    the precondition for fusing a later overlap window without a barrier."""
    ev = trace.events
    if not trace._structured or MATCH not in ev:
        return False
    match = np.asarray(ev.column(MATCH), np.int64)
    et = ev.cat(ET)
    is_call = et.mask_eq(ENTER) | et.mask_eq(LEAVE)
    return not bool(np.any(is_call & (match < 0)))


def _and_masks(masks: List[np.ndarray]) -> np.ndarray:
    m = masks[0]
    for x in masks[1:]:
        m = m & x
    return m


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------

class _TraceSource:
    def __init__(self, trace):
        self.trace = trace

    def load(self, procs=None, proc_bounds=None):
        return self.trace

    def describe(self) -> str:
        return f"trace({getattr(self.trace, 'label', None)!r}, " \
               f"{len(self.trace)} events)"


class _StreamSource:
    """Out-of-core source: a :class:`~repro.core.streaming.StreamingTrace`
    handle.  Terminal ops with a registered streaming form execute chunk by
    chunk; ``collect()`` (and ops without one) materialize explicitly."""

    def __init__(self, handle):
        self.handle = handle

    def load(self, procs=None, proc_bounds=None):
        return self.handle.load_raw(procs=procs, proc_bounds=proc_bounds)

    def describe(self) -> str:
        h = self.handle
        return (f"stream({len(h.paths)} path(s), format={h.format!r}, "
                f"chunk_rows={h.chunk_rows})")


class _ScanSource:
    """Deferred sharded ingest: paths are read (in parallel) at collect time,
    after the plan's process restriction is known, so excluded shards are
    never parsed."""

    def __init__(self, paths: Sequence[str], format: str = "auto",
                 processes: Optional[int] = None, label: Optional[str] = None):
        self.paths = list(paths)
        self.format = format
        self.processes = processes
        self.label = label

    def load(self, procs=None, proc_bounds=None):
        from ..readers.parallel import read_parallel
        return read_parallel(self.paths, kind=self.format,
                             processes=self.processes, label=self.label,
                             procs=procs, proc_bounds=proc_bounds)

    def describe(self) -> str:
        return f"scan({len(self.paths)} shard(s), format={self.format!r})"


# ---------------------------------------------------------------------------
# the query object
# ---------------------------------------------------------------------------

class TraceQuery:
    """An immutable logical plan over a trace source.

    Builder methods return a *new* query (plans share prefixes freely);
    nothing touches event data until :meth:`collect` or a terminal analysis
    op registered in :mod:`repro.core.registry`.
    """

    def __init__(self, source, steps: Optional[Sequence[Step]] = None):
        self._source = source
        self._steps: Tuple[Step, ...] = tuple(steps or ())

    # -- construction ------------------------------------------------------
    @classmethod
    def from_trace(cls, trace) -> "TraceQuery":
        return cls(_TraceSource(trace))

    def _with(self, step: Step) -> "TraceQuery":
        return TraceQuery(self._source, self._steps + (step,))

    def filter(self, f: Filter) -> "TraceQuery":
        q = self
        for step in _decompose_filter(f):
            q = q._with(step)
        return q

    def slice_time(self, start: float, end: float,
                   trim: str = "overlap") -> "TraceQuery":
        return self._with(SliceTimeStep(start, end, trim))

    def restrict_processes(self, procs: Sequence[int]) -> "TraceQuery":
        return self._with(ProcessStep(procs))

    # the eager Trace method name, for symmetric chaining
    filter_processes = restrict_processes

    # -- planner introspection --------------------------------------------
    def _proc_restriction(self):
        """Conjunction of every step's process restriction: (bounds, set)."""
        bounds = None
        pset = None
        for step in self._steps:
            b, s = step.proc_hint()
            if b is not None:
                bounds = b if bounds is None else (max(bounds[0], b[0]),
                                                   min(bounds[1], b[1]))
            if s is not None:
                pset = s if pset is None else (pset & s)
        return bounds, pset

    def explain(self) -> str:
        """Human-readable plan: fused segments and pushdown restrictions.

        Mirrors collect()'s barrier decisions; a barrier that depends on
        runtime state (unmatched calls in the frame) is marked conditional.
        """
        lines = [f"source: {self._source.describe()}"]
        bounds, pset = self._proc_restriction()
        if isinstance(self._source, _ScanSource) and (bounds or pset is not None):
            lines.append(f"pushdown: procs={sorted(pset) if pset else None} "
                         f"bounds={bounds}")
        seg = 0
        pending = False
        pair_preserving = True
        for step in self._steps:
            if step.reads_derived():
                if pending:
                    seg += 1
                    lines.append("-- materialize (derived-value barrier) --")
                    pending = False
                pair_preserving = False
            elif step.needs_structure():
                if pending and not pair_preserving:
                    seg += 1
                    lines.append("-- materialize (structure barrier) --")
                    pending = False
                    pair_preserving = True
                elif pending:
                    lines.append("   (fuses with pair-preserving selections; "
                                 "barrier only if the frame has unmatched "
                                 "calls)")
            elif not isinstance(step, ProcessStep):
                pair_preserving = False
            lines.append(f"segment {seg}: {step.describe()}")
            pending = True
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return f"TraceQuery({len(self._steps)} step(s))"

    # -- execution ---------------------------------------------------------
    def collect(self):
        """Execute the plan and return the resulting Trace.

        A zero-step plan is the identity: it returns the source trace object
        itself (deliberately shared, so prerequisite materialization by
        terminal ops caches onto the source exactly like the eager methods).
        Any plan with steps returns a fresh Trace.

        Consecutive structure-independent selections are fused into a single
        mask.  A structure-dependent step (call-interval window) normally
        flushes pending masks first (one materialization) so its mask sees
        the structure of the selected frame — except when every pending mask
        is itself an overlap window on a fully matched frame: such
        selections keep enter/leave pairs, subtrees, and therefore per-row
        (ts, match_ts) intact, so the next window mask evaluated on the
        *base* frame is identical and the whole run of windows fuses into
        one materialization.  A predicate over derived *value* columns
        (time.inc/time.exc/_depth/...) always flushes first: those values
        change with the selection, and the eager chain sees the recomputed
        ones.
        """
        bounds, pset = self._proc_restriction()
        cur = self._source.load(procs=pset, proc_bounds=bounds)
        if len(cur.events) == 0 and self._steps:
            # nothing to select from (e.g. every shard skipped); still hand
            # back a fresh Trace — selection must never alias its source
            return type(cur)(_strip(cur.events), definitions=cur.definitions,
                             label=cur.label)
        masks: List[np.ndarray] = []
        pair_preserving = True  # every pending mask keeps call pairs intact
        for step in self._steps:
            if step.reads_derived():
                # derived values (inc/exc/depth/...) change with the
                # selection itself: flush unconditionally, recompute/remap,
                # then evaluate on the frame the eager chain would see
                if masks:
                    cur = apply_selection(cur, _and_masks(masks))
                    masks = []
                cur._ensure_structure()
                masks.append(step.mask(cur))
                pair_preserving = False
            elif step.needs_structure():
                if masks and pair_preserving:
                    # the fusion check needs matching columns; pending masks
                    # are pair-preserving, so structure computed here remaps
                    # through them if we do end up flushing
                    cur._ensure_structure()
                if masks and not (pair_preserving and _fully_matched(cur)):
                    cur = apply_selection(cur, _and_masks(masks))
                    masks = []
                    pair_preserving = True
                cur._ensure_structure()
                masks.append(step.mask(cur))
            else:
                masks.append(step.mask(cur))
                if not isinstance(step, ProcessStep):
                    # arbitrary predicates may split enter/leave pairs;
                    # process subsets keep whole timelines
                    pair_preserving = False
        if masks:
            cur = apply_selection(cur, _and_masks(masks))
        return cur

    # -- terminal analysis ops (registry-resolved) -------------------------
    def run(self, op_name: str, *args: Any, **kwargs: Any) -> Any:
        """Execute a registered terminal op over this plan.

        ``cache=`` (consumed here, never passed to the op) controls the
        plan-result cache (:mod:`repro.core.plancache`): ``False`` bypasses
        it, ``True`` opts an in-memory trace into content-hashed caching;
        the default caches streaming/scan sources only.
        """
        cache_flag = kwargs.pop("cache", None)
        spec = registry.get_op(op_name)
        if spec is None:
            raise ValueError(f"unknown analysis op {op_name!r}; "
                             f"registered: {registry.list_ops()}")
        if spec.scope == "set":
            raise ValueError(
                f"{op_name!r} is a multi-trace comparison op; run it on a "
                f"TraceSet (repro.core.diff.TraceSet) instead of a "
                f"single-trace query")
        from . import plancache
        key = plancache.plan_key(self._source, self._steps, spec, args,
                                 kwargs, cache_flag)
        if key is not None:
            hit, value = plancache.lookup(key)
            if hit:
                return value
        if isinstance(self._source, _StreamSource):
            # out-of-core execution: fused masks run per chunk and the op's
            # combinable partial aggregates merge across chunks.  Ops
            # without a streaming form raise StreamingUnsupported with the
            # escape hatches spelled out.
            from .streaming import execute_streaming
            result = execute_streaming(self._source.handle, self._steps,
                                       spec, args, kwargs,
                                       cache_flag=cache_flag)
        else:
            trace = self.collect()
            if spec.needs_structure:
                trace._ensure_structure()
            if spec.needs_messages:
                trace._ensure_messages()
            result = spec.fn(trace, *args, **kwargs)
        if key is not None:
            plancache.store(key, result)
        return result

    def __getattr__(self, name: str):
        return registry.terminal_op(name, self.run, "TraceQuery")


def scan(paths, format: str = "auto", processes: Optional[int] = None,
         label: Optional[str] = None) -> TraceQuery:
    """Build a query over on-disk shards without reading them yet.

    ``paths`` is one path or a sequence of per-location shard paths; shards
    excluded by the plan's process restriction are skipped before parsing.
    """
    if isinstance(paths, (str, bytes)):
        paths = [paths]
    return TraceQuery(_ScanSource(paths, format=format, processes=processes,
                                  label=label))
