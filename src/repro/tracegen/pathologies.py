"""Ground-truth pathology injection for closed-loop detector tests.

The diagnostics suite (:mod:`repro.core.detectors`) is only trustworthy if
each detector provably recovers a *known* problem and stays silent on a
problem-free trace.  This module supplies both halves:

* :func:`baseline` — a deliberately clean bulk-synchronous app: every rank
  does identical work, every message is sent well before its receiver
  needs it, both threads per rank share the load exactly, and iterations
  align 1:1 with the default efficiency windows.  Every registered
  detector returns zero findings on it at default thresholds.
* :func:`inject` — ``inject(events, pathology, magnitude, seed) ->
  (events, GroundTruth)``: surgically introduces one pathology into any
  app trace, returning machine-readable ground truth (which rank /
  function / time window the detector must name, at top-1).

Injections are pure timestamp/name edits in integer nanoseconds, so the
result is a valid trace by construction: per-(process, thread) Enter/Leave
nesting is preserved (timelines are stretched or shifted monotonically per
thread), and the edited frame round-trips through every on-disk format.
``magnitude`` scales the injected effect, so detector severity must grow
monotonically with it — the closed-loop property tests assert exactly
that.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import numpy as np

from ..core.constants import (DERIVED_COLUMNS, ENTER, ET, LEAVE, MATCH,
                              MPI_SEND, NAME, PROC, THREAD, TS)
from ..core.detectors import _window_edges, is_comm_name
from ..core.frame import EventFrame
from ..core.trace import Trace
from .builder import TraceBuilder

__all__ = ["GroundTruth", "PATHOLOGIES", "baseline", "inject",
           "pathology_trace"]

#: pathology name -> the detector that must recover it at top-1
PATHOLOGIES = {
    "late_sender": "late_sender",
    "straggler": "stragglers",
    "serialization": "serialization",
    "imbalance": "imbalance_root_cause",
    "efficiency_drop": "pop_efficiency",
}


@dataclass(frozen=True)
class GroundTruth:
    """Machine-readable record of an injected pathology: what a correct
    detector must report.  ``process`` is -1 and ``function`` is ``""``
    where the pathology has no rank/function locality (then the time
    window carries the signal)."""

    pathology: str
    detector: str
    process: int
    function: str
    t_start: float
    t_end: float
    magnitude: float
    seed: int


# ---------------------------------------------------------------------------
# the clean baseline app
# ---------------------------------------------------------------------------

def baseline(nprocs: int = 4, iters: int = 16, seed: int = 0,
             with_threads: bool = True) -> Trace:
    """A pathology-free bulk-synchronous app every detector is silent on.

    Per iteration each rank computes (identical duration on every rank),
    sends to its ring successor, then receives from its predecessor —
    always after the matching send was posted, with a constant pick-up
    lag.  With ``with_threads`` a second thread carries exactly the same
    nesting-weighted busy time as the first.  Iteration length divides the
    trace span exactly, so the default 16 efficiency windows see identical
    activity and the POP detector's median gate stays silent.
    """
    rng = np.random.default_rng(seed)  # reserved: keeps signature uniform
    del rng
    b = TraceBuilder(with_threads=with_threads)
    compute_d, send_d, recv_d = 4000, 400, 600
    iter_d = compute_d + send_d + recv_d
    for p in range(nprocs):
        t = 0
        for _ in range(iters):
            b.enter(t, "iteration", p)
            if with_threads:
                # same window, same nesting-weighted busy time as thread 0
                b.enter(t, "overlap_shell", p, thread=1)
                b.call(t, iter_d, "overlap_compute", p, thread=1)
                b.leave(t + iter_d, "overlap_shell", p, thread=1)
            t = b.call(t, compute_d, "compute", p)
            t = b.send(t, send_d, p, (p + 1) % nprocs, 1024.0)
            t = b.recv(t, recv_d, p, (p - 1) % nprocs, 1024.0)
            b.leave(t, "iteration", p)
    return b.trace(label=f"baseline({nprocs}x{iters})")


# ---------------------------------------------------------------------------
# injection plumbing
# ---------------------------------------------------------------------------

def _fresh_events(source: Union[Trace, EventFrame]) -> EventFrame:
    """A mutable copy of the raw event columns (derived structure, which
    would be invalidated by timestamp edits, is dropped)."""
    ev = source.events if isinstance(source, Trace) else source
    return ev.drop(*DERIVED_COLUMNS).copy()


def _structured(ev: EventFrame) -> Trace:
    """A throwaway Trace over a copy of ``ev`` with enter/leave matching
    materialized — row indices align with ``ev`` (same order)."""
    tr = Trace.from_events(ev.copy())
    tr._ensure_structure()
    return tr


def _resort(ev: EventFrame) -> EventFrame:
    """Restore the canonical (process, time) order trace files use."""
    return ev.sort_by([PROC, TS])


def _int_ts(ev: EventFrame) -> np.ndarray:
    return np.asarray(ev[TS], np.float64).astype(np.int64)


def _stretch(ts: np.ndarray, rows: np.ndarray, factor: float) -> None:
    """Stretch the selected rows' timeline about its own start by
    ``factor`` (monotone, exact integers — nesting survives)."""
    if len(rows) == 0:
        return
    t0 = ts[rows].min()
    ts[rows] = t0 + np.rint((ts[rows] - t0) * factor).astype(np.int64)


def _apply_ts(ev: EventFrame, ts: np.ndarray) -> EventFrame:
    ev[TS] = ts.astype(np.float64)
    return _resort(ev)


# ---------------------------------------------------------------------------
# injectors
# ---------------------------------------------------------------------------

def _inject_late_sender(ev, magnitude, rng, delay_frac: float = 0.02):
    """Delay one rank's MpiSend instants until after their matched
    receivers are already waiting — injected receiver wait scales with
    ``magnitude`` (≈ ``delay_frac * magnitude`` of the trace span per
    message source rank)."""
    tr = _structured(ev)
    tr._ensure_messages()
    mm = tr._msg_match
    name = ev.cat(NAME)
    ts = _int_ts(ev)
    sends = np.nonzero(name.mask_eq(MPI_SEND) & (mm >= 0))[0]
    if len(sends) == 0:
        raise ValueError("trace has no matched messages to make late")
    proc = np.asarray(ev[PROC], np.int64)
    culprit = int(rng.choice(np.unique(proc[sends])))
    mine = sends[proc[sends] == culprit]
    span = int(ts.max() - ts.min())
    lag = ts[mm[mine]] - ts[mine]
    # past every matched recv, plus a magnitude-scaled wait per message
    delay = int(lag.max()) + max(
        int(round(delay_frac * magnitude * span)) // max(len(mine), 1), 1)
    ts[mine] += delay
    out = _apply_ts(ev, ts)
    return out, GroundTruth(
        pathology="late_sender", detector="late_sender", process=culprit,
        function=MPI_SEND, t_start=float(ts[mine].min()),
        t_end=float(ts[mine].max()), magnitude=magnitude, seed=-1)


def _inject_straggler(ev, magnitude, rng):
    """Stretch one rank's entire timeline by ``magnitude`` — its work
    grows proportionally while everyone else stands still."""
    proc = np.asarray(ev[PROC], np.int64)
    culprit = int(rng.choice(np.unique(proc)))
    ts = _int_ts(ev)
    rows = np.nonzero(proc == culprit)[0]
    _stretch(ts, rows, magnitude)
    out = _apply_ts(ev, ts)
    return out, GroundTruth(
        pathology="straggler", detector="stragglers", process=culprit,
        function="", t_start=float(ts[rows].min()),
        t_end=float(ts[rows].max()), magnitude=magnitude, seed=-1)


def _inject_serialization(ev, magnitude, rng):
    """Pile one rank's overlapped work onto thread 0: thread 0's timeline
    is stretched by ``1 + magnitude`` while its other threads shrink by
    the same factor, so the dominant-thread share grows monotonically
    with ``magnitude``."""
    if THREAD not in ev:
        raise ValueError("serialization injection needs a threaded trace "
                         "(e.g. pathologies.baseline(with_threads=True))")
    proc = np.asarray(ev[PROC], np.int64)
    thread = np.asarray(ev[THREAD], np.int64)
    multi = np.unique(proc[thread > 0])
    if len(multi) == 0:
        raise ValueError("no rank has events on more than one thread")
    culprit = int(rng.choice(multi))
    factor = 1.0 + magnitude
    ts = _int_ts(ev)
    _stretch(ts, np.nonzero((proc == culprit) & (thread == 0))[0], factor)
    for t in np.unique(thread[(proc == culprit) & (thread > 0)]):
        _stretch(ts, np.nonzero((proc == culprit) & (thread == t))[0],
                 1.0 / factor)
    rows = np.nonzero(proc == culprit)[0]
    out = _apply_ts(ev, ts)
    return out, GroundTruth(
        pathology="serialization", detector="serialization", process=culprit,
        function="", t_start=float(ts[rows].min()),
        t_end=float(ts[rows].max()), magnitude=magnitude, seed=-1)


def _inject_imbalance(ev, magnitude, rng, function: Optional[str] = None):
    """Dilate one function's calls on one rank by ``magnitude``: each
    targeted call gets ``(magnitude - 1) x`` its duration appended, and
    everything after it on that rank shifts right — nesting intact, other
    ranks untouched."""
    tr = _structured(ev)
    sev = tr.events
    match = np.asarray(sev.column(MATCH), np.int64)
    ts = _int_ts(ev)
    proc = np.asarray(ev[PROC], np.int64)
    is_enter = sev.cat(ET).mask_eq(ENTER)
    names = ev.cat(NAME)
    culprit = int(rng.choice(np.unique(proc)))
    cand = np.nonzero(is_enter & (proc == culprit) & (match >= 0))[0]
    cand = cand[~np.asarray([is_comm_name(c)
                             for c in names.categories])[names.codes[cand]]]
    if len(cand) == 0:
        raise ValueError(f"rank {culprit} has no non-communication calls")
    if function is None:
        # the heaviest computation on the culprit rank, by exclusive time
        # (what the detector itself ranks by)
        from ..core.constants import EXC
        exc = np.nan_to_num(np.asarray(sev.column(EXC), np.float64))
        per = {}
        for i, d in zip(names.codes[cand], exc[cand]):
            per[i] = per.get(i, 0) + int(d)
        function = str(names.categories[max(per, key=per.get)])
    hits = cand[np.asarray([str(names.categories[c]) == function
                            for c in names.codes[cand]])]
    if len(hits) == 0:
        raise ValueError(f"rank {culprit} never calls {function!r}")
    leaves = match[hits]
    extras = np.rint((magnitude - 1.0) * (ts[leaves] - ts[hits])
                     ).astype(np.int64)
    # the dilated Leave and every event after it *in sequence order* shift
    # by the accumulated extra — per thread, so a call dilated on one
    # thread never stretches calls open on the culprit's other threads,
    # and (the frame being timestamp-sorted with stable within-ts order,
    # inner leaves before outer) a nested call ending at the exact same
    # timestamp as the dilated call's Leave keeps its duration
    thread = (np.asarray(ev[THREAD], np.int64) if THREAD in ev
              else np.zeros(len(ev), np.int64))
    for t in np.unique(thread[hits]):
        rows_t = np.nonzero((proc == culprit) & (thread == t))[0]
        delta = np.zeros(len(rows_t), np.int64)
        on_t = thread[hits] == t
        pos = np.searchsorted(rows_t, leaves[on_t])
        np.add.at(delta, pos, extras[on_t])
        ts[rows_t] += np.cumsum(delta)
    rows = np.nonzero(proc == culprit)[0]
    out = _apply_ts(ev, ts)
    return out, GroundTruth(
        pathology="imbalance", detector="imbalance_root_cause",
        process=culprit, function=function, t_start=float(ts[rows].min()),
        t_end=float(ts[rows].max()), magnitude=magnitude, seed=-1)


def _inject_efficiency_drop(ev, magnitude, rng, num_windows: int = 16,
                            window: Optional[int] = None):
    """Turn computation inside one time window into waiting: a
    ``magnitude`` fraction (clipped to [0, 1]) of the non-communication
    calls entered in that window are renamed to ``MPI_Wait`` — no
    timestamp moves, so the window alignment stays exact while its
    communication efficiency collapses."""
    tr = _structured(ev)
    match = np.asarray(tr.events.column(MATCH), np.int64)
    ts = _int_ts(ev)
    edges = _window_edges(int(ts.min()), int(ts.max()), num_windows)
    w = int(num_windows // 2 if window is None else window)
    is_enter = tr.events.cat(ET).mask_eq(ENTER)
    names = ev.cat(NAME)
    comm = np.asarray([is_comm_name(c) for c in names.categories])
    cand = np.nonzero(is_enter & (match >= 0) & ~comm[names.codes]
                      & (ts >= edges[w]) & (ts < edges[w + 1]))[0]
    if len(cand) == 0:
        raise ValueError(f"window {w} has no computation to degrade")
    frac = float(np.clip(magnitude, 0.0, 1.0))
    k = max(int(round(frac * len(cand))), 1)
    hits = np.sort(rng.choice(cand, size=k, replace=False))
    new_names = np.asarray([str(s) for s in ev[NAME]], dtype=object)
    new_names[hits] = "MPI_Wait"
    new_names[match[hits]] = "MPI_Wait"
    ev[NAME] = new_names
    return _resort(ev), GroundTruth(
        pathology="efficiency_drop", detector="pop_efficiency", process=-1,
        function="", t_start=float(edges[w]), t_end=float(edges[w + 1]),
        magnitude=magnitude, seed=-1)


_INJECTORS = {
    "late_sender": _inject_late_sender,
    "straggler": _inject_straggler,
    "serialization": _inject_serialization,
    "imbalance": _inject_imbalance,
    "efficiency_drop": _inject_efficiency_drop,
}


def inject(events: Union[Trace, EventFrame], pathology: str,
           magnitude: float = 2.0, seed: int = 0,
           **kwargs) -> Tuple[EventFrame, GroundTruth]:
    """Inject ``pathology`` into a trace, returning the edited events and
    the ground truth the matching detector must recover.

    Args:
        events: source app trace (``Trace`` or raw ``EventFrame``) — never
            mutated; a fresh frame is returned.
        pathology: one of :data:`PATHOLOGIES`.
        magnitude: effect size (semantics per injector docstring);
            detector severity grows monotonically with it.
        seed: rng seed for culprit selection.
        **kwargs: injector-specific knobs (``function=`` for imbalance,
            ``window=``/``num_windows=`` for efficiency_drop, ...).

    Returns:
        ``(events, GroundTruth)``.
    """
    if pathology not in _INJECTORS:
        raise ValueError(f"unknown pathology {pathology!r}; one of "
                         f"{sorted(_INJECTORS)}")
    rng = np.random.default_rng(seed)
    out, gt = _INJECTORS[pathology](_fresh_events(events), float(magnitude),
                                    rng, **kwargs)
    return out, GroundTruth(
        pathology=gt.pathology, detector=gt.detector, process=gt.process,
        function=gt.function, t_start=gt.t_start, t_end=gt.t_end,
        magnitude=gt.magnitude, seed=seed)


def pathology_trace(pathology: str, nprocs: int = 4, iters: int = 16,
                    magnitude: float = 2.0, seed: int = 0,
                    **kwargs) -> Tuple[Trace, GroundTruth]:
    """Convenience: :func:`baseline` + :func:`inject` in one call."""
    base = baseline(nprocs=nprocs, iters=iters, seed=seed)
    ev, gt = inject(base, pathology, magnitude=magnitude, seed=seed,
                    **kwargs)
    return Trace.from_events(ev, label=f"{pathology}(m={magnitude:g})"), gt
