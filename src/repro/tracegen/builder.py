"""Append-oriented trace builder producing columnar EventFrames."""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.constants import (ENTER, ET, LEAVE, MPI_RECV, MPI_SEND, MSG_SIZE,
                              NAME, PARTNER, PROC, TAG, THREAD, TS)
from ..core.frame import EventFrame
from ..core.trace import Trace

__all__ = ["TraceBuilder"]


class TraceBuilder:
    """Accumulates events in Python lists, emits one columnar EventFrame.

    Generators work per-process with a local clock; ``call``/``send``/``recv``
    advance and return the clock so loops read naturally.
    """

    def __init__(self, with_threads: bool = False):
        self.ts: list = []
        self.et: list = []
        self.name: list = []
        self.proc: list = []
        self.thread: list = []
        self.partner: list = []
        self.size: list = []
        self.tag: list = []
        self.with_threads = with_threads

    # -- primitive events ---------------------------------------------------
    def event(self, ts: float, et: str, name: str, proc: int, thread: int = 0,
              partner: int = -1, size: float = np.nan, tag: int = 0) -> None:
        self.ts.append(ts)
        self.et.append(et)
        self.name.append(name)
        self.proc.append(proc)
        self.thread.append(thread)
        self.partner.append(partner)
        self.size.append(size)
        self.tag.append(tag)

    def enter(self, ts, name, proc, thread=0):
        self.event(ts, ENTER, name, proc, thread)

    def leave(self, ts, name, proc, thread=0):
        self.event(ts, LEAVE, name, proc, thread)

    def call(self, t0: float, dur: float, name: str, proc: int, thread: int = 0
             ) -> float:
        """Enter at t0, Leave at t0+dur; returns the new clock."""
        self.enter(t0, name, proc, thread)
        self.leave(t0 + dur, name, proc, thread)
        return t0 + dur

    def send(self, t0: float, dur: float, proc: int, dst: int, nbytes: float,
             tag: int = 0, thread: int = 0, name: str = "MPI_Send") -> float:
        """A send call wrapping an MpiSend instant at its midpoint."""
        self.enter(t0, name, proc, thread)
        self.event(t0 + dur * 0.5, "MpiSend", MPI_SEND, proc, thread,
                   partner=dst, size=nbytes, tag=tag)
        self.leave(t0 + dur, name, proc, thread)
        return t0 + dur

    def recv(self, t0: float, dur: float, proc: int, src: int, nbytes: float,
             tag: int = 0, thread: int = 0, name: str = "MPI_Recv") -> float:
        self.enter(t0, name, proc, thread)
        self.event(t0 + dur * 0.9, "MpiRecv", MPI_RECV, proc, thread,
                   partner=src, size=nbytes, tag=tag)
        self.leave(t0 + dur, name, proc, thread)
        return t0 + dur

    # -- output ---------------------------------------------------------------
    def frame(self) -> EventFrame:
        ev = EventFrame({
            TS: np.asarray(self.ts, np.float64),
            ET: np.asarray(self.et),
            NAME: np.asarray(self.name),
            PROC: np.asarray(self.proc, np.int64),
            PARTNER: np.asarray(self.partner, np.int64),
            MSG_SIZE: np.asarray(self.size, np.float64),
            TAG: np.asarray(self.tag, np.int64),
        })
        if self.with_threads:
            ev[THREAD] = np.asarray(self.thread, np.int64)
        # canonical (process, time) order like real trace files
        return ev.sort_by([PROC, TS])

    def trace(self, label: Optional[str] = None) -> Trace:
        return Trace.from_events(self.frame(), label=label)
