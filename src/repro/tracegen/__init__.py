"""Synthetic-but-structured parallel application trace generators.

The paper's case studies (§VII) analyze traces of real MPI/Charm++/PyTorch
applications (AMG, Laghos, Kripke, Tortuga, Loimos, AxoNN).  Those apps cannot
run in this container, so we generate traces that preserve the *communication
and call structure* each case study analyzes:

* :func:`gol`            — near-neighbor 1-D halo exchange (Game of Life §VII-C)
* :func:`stencil3d`      — 3-D nearest-neighbor exchange (Laghos-like comm matrix)
* :func:`amg_vcycle`     — V-cycle with shrinking messages + coarse all-reduce (AMG)
* :func:`kripke_sweep`   — wavefront dependency chain (Kripke)
* :func:`tortuga`        — CFD iteration (computeRhs/gradC2C/ghost exchange) with
                           configurable scaling degradation (Tortuga §VII-B/D)
* :func:`loimos`         — imbalanced actor-style message processing (Loimos §VII-A)
* :func:`axonn_training` — bulk-synchronous training loop at three optimization
                           levels (AxoNN §VII-D: v0 no overlap, v1 less comm,
                           v2 comm/comp overlap on a second "stream" thread)

All generators are deterministic given ``seed`` and return
:class:`repro.core.Trace` objects.  :func:`big_trace` is the out-of-core
exception: it *writes sharded JSONL to disk* in bounded batches — traces
far larger than RAM for exercising the streaming engine.
"""

from .builder import TraceBuilder
from .apps import (amg_vcycle, axonn_training, gol, kripke_sweep, loimos,
                   regression_pair, stencil3d, tortuga)
from .big import big_trace
from .pathologies import (GroundTruth, PATHOLOGIES, baseline, inject,
                          pathology_trace)

__all__ = [
    "TraceBuilder", "gol", "stencil3d", "amg_vcycle", "kripke_sweep",
    "tortuga", "loimos", "axonn_training", "regression_pair", "big_trace",
    "GroundTruth", "PATHOLOGIES", "baseline", "inject", "pathology_trace",
]
