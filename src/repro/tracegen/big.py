"""Out-of-core trace generation: write arbitrarily large sharded traces
without ever holding them in memory.

:func:`big_trace` emits one JSONL shard per rank (``rank_<p>.jsonl`` — the
layout the parallel driver's shard hints understand) in bounded batches:
events are generated vectorized with NumPy and formatted straight to disk,
so generating a 10M-event trace costs a few hundred MB of *file*, not RAM.
The trace shape stress-tests the streaming engine on purpose: every rank
runs inside one ``main()`` call spanning the whole shard, each iteration is
wrapped in an ``iteration`` call spanning many leaf calls (so wrapper pairs
split across chunk boundaries at any chunk size), and leaf compute/comm
calls carry message instants for the communication ops.
"""

from __future__ import annotations

import os
from typing import List, Optional

import numpy as np

__all__ = ["big_trace"]

_US = 1_000  # ns


def big_trace(out_dir: str, nprocs: int = 8, events_per_proc: int = 125_000,
              calls_per_iter: int = 500, seed: int = 0,
              batch_calls: int = 50_000) -> List[str]:
    """Write a sharded synthetic trace of ``nprocs * events_per_proc``
    events without holding it in memory; returns the shard paths.

    Each rank's stream is, in time order::

        Enter main()
          Enter iteration / [compute_cells() | halo_exchange() + MpiSend +
          MpiRecv] x calls_per_iter / Leave iteration
          ... repeated ...
        Leave main()

    so ``main()`` spans the whole shard and every ``iteration`` wrapper
    spans ~3 x calls_per_iter rows — guaranteed enter/leave pairs split
    across chunk boundaries for any realistic ``chunk_rows``.

    Args:
        out_dir: directory for ``rank_<p>.jsonl`` shards (created).
        nprocs: number of ranks (one shard each).
        events_per_proc: approximate events per shard (rounded to whole
            iterations).
        calls_per_iter: leaf calls per ``iteration`` wrapper.
        seed: RNG seed (per-rank streams derive from it deterministically).
        batch_calls: leaf calls generated and formatted per write batch —
            bounds generator memory.

    Returns:
        List of shard paths, rank order.
    """
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for p in range(nprocs):
        path = os.path.join(out_dir, f"rank_{p}.jsonl")
        _write_rank(path, p, nprocs, events_per_proc, calls_per_iter,
                    seed, batch_calls)
        paths.append(path)
    return paths


def _write_rank(path: str, p: int, nprocs: int, events_per_proc: int,
                calls_per_iter: int, seed: int, batch_calls: int) -> None:
    rng = np.random.default_rng(seed * 100_003 + p)
    # rows per leaf call: 2 (enter/leave); every 8th call adds a message
    # instant; each iteration adds 2 wrapper rows.  Solve for leaf count.
    rows_per_call = 2 + 1 / 8
    n_iters = max(1, int((events_per_proc - 2)
                         / (calls_per_iter * rows_per_call + 2)))
    with open(path, "w") as f:
        t = 0
        f.write(f'{{"ts":{t},"et":"Enter","name":"main()","proc":{p}}}\n')
        leaf_names = ("compute_cells()", "halo_exchange()", "smooth()")
        for it in range(n_iters):
            f.write(f'{{"ts":{t},"et":"Enter","name":"iteration",'
                    f'"proc":{p}}}\n')
            done = 0
            while done < calls_per_iter:
                k = min(batch_calls, calls_per_iter - done)
                t = _write_batch(f, rng, p, nprocs, t, k, it, leaf_names)
                done += k
            t += 2 * _US
            f.write(f'{{"ts":{t},"et":"Leave","name":"iteration",'
                    f'"proc":{p}}}\n')
        t += 5 * _US
        f.write(f'{{"ts":{t},"et":"Leave","name":"main()","proc":{p}}}\n')


def _write_batch(f, rng, p: int, nprocs: int, t: int, k: int, tag: int,
                 leaf_names) -> int:
    """Vectorized: k leaf calls -> formatted lines -> one writelines."""
    durs = rng.integers(5 * _US, 40 * _US, size=k)
    which = rng.integers(0, len(leaf_names), size=k)
    starts = t + np.concatenate([[0], np.cumsum(durs[:-1])])
    ends = starts + durs
    msg_at = np.arange(k) % 8 == 7  # every 8th call sends
    dst = (p + 1) % nprocs
    sizes = rng.integers(256, 8192, size=k)
    lines = []
    for i in range(k):
        nm = leaf_names[which[i]]
        lines.append(f'{{"ts":{starts[i]},"et":"Enter","name":"{nm}",'
                     f'"proc":{p}}}\n')
        if msg_at[i]:
            mid = (starts[i] + ends[i]) // 2
            lines.append(f'{{"ts":{mid},"et":"Instant","name":"MpiSend",'
                         f'"proc":{p},"partner":{dst},"size":{sizes[i]},'
                         f'"tag":{tag}}}\n')
        lines.append(f'{{"ts":{ends[i]},"et":"Leave","name":"{nm}",'
                     f'"proc":{p}}}\n')
    f.writelines(lines)
    return int(ends[-1]) if k else t
