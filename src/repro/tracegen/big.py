"""Out-of-core trace generation: write arbitrarily large sharded traces
without ever holding them in memory.

:func:`big_trace` emits one shard per rank in bounded batches: events are
generated vectorized with NumPy and serialized straight to disk, so
generating a 10M-event trace costs a few hundred MB of *file*, not RAM.
``format="jsonl"`` (default) writes ``rank_<p>.jsonl`` text shards — the
layout the parallel driver's shard hints understand; ``format="pack"``
writes ``rank_<p>.pack`` columnar binary shards directly (no text round
trip: column batches stream into a :class:`~repro.readers.pack.PackWriter`,
and each shard gets a structure sidecar), which is both ~5x smaller on disk
and the fast path for every reopen.  Both formats emit the *same logical
events* for the same parameters (identical RNG draws), so analysis results
agree across them.

The trace shape stress-tests the streaming engine on purpose: every rank
runs inside one ``main()`` call spanning the whole shard, each iteration is
wrapped in an ``iteration`` call spanning many leaf calls (so wrapper pairs
split across chunk boundaries at any chunk size), and leaf compute/comm
calls carry message instants for the communication ops.
"""

from __future__ import annotations

import os
from typing import Iterator, List, Tuple

import numpy as np

__all__ = ["big_trace"]

_US = 1_000  # ns

# name table (codes are batch-local positions here; writers re-intern)
_NAMES = ("main()", "iteration", "compute_cells()", "halo_exchange()",
          "smooth()", "MpiSend")
_MAIN, _ITER, _LEAF0, _MPISEND = 0, 1, 2, 5
_LEAF_NAMES = (2, 3, 4)
# event-type codes match the on-disk convention: Enter=0 / Leave=1 / Instant=2
_ENTER, _LEAVE, _INSTANT = 0, 1, 2


def big_trace(out_dir: str, nprocs: int = 8, events_per_proc: int = 125_000,
              calls_per_iter: int = 500, seed: int = 0,
              batch_calls: int = 50_000, format: str = "jsonl") -> List[str]:
    """Write a sharded synthetic trace of ``nprocs * events_per_proc``
    events without holding it in memory; returns the shard paths.

    Each rank's stream is, in time order::

        Enter main()
          Enter iteration / [compute_cells() | halo_exchange() + MpiSend +
          MpiRecv] x calls_per_iter / Leave iteration
          ... repeated ...
        Leave main()

    so ``main()`` spans the whole shard and every ``iteration`` wrapper
    spans ~3 x calls_per_iter rows — guaranteed enter/leave pairs split
    across chunk boundaries for any realistic ``chunk_rows``.

    Args:
        out_dir: directory for ``rank_<p>.<ext>`` shards (created).
        nprocs: number of ranks (one shard each).
        events_per_proc: approximate events per shard (rounded to whole
            iterations).
        calls_per_iter: leaf calls per ``iteration`` wrapper.
        seed: RNG seed (per-rank streams derive from it deterministically).
        batch_calls: leaf calls generated and serialized per write batch —
            bounds generator memory.
        format: ``"jsonl"`` (text shards) or ``"pack"`` (columnar binary
            shards with structure sidecars, written directly).

    Returns:
        List of shard paths, rank order.
    """
    if format not in ("jsonl", "pack"):
        raise ValueError(f'format must be "jsonl" or "pack", got {format!r}')
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for p in range(nprocs):
        path = os.path.join(out_dir, f"rank_{p}.{format}")
        if format == "jsonl":
            _write_rank_jsonl(path, p, nprocs, events_per_proc,
                              calls_per_iter, seed, batch_calls)
        else:
            _write_rank_pack(path, p, nprocs, events_per_proc,
                             calls_per_iter, seed, batch_calls)
        paths.append(path)
    return paths


# ---------------------------------------------------------------------------
# shared vectorized event stream (one source of truth for both formats)
# ---------------------------------------------------------------------------

def _rank_batches(p: int, nprocs: int, events_per_proc: int,
                  calls_per_iter: int, seed: int, batch_calls: int
                  ) -> Iterator[Tuple[np.ndarray, ...]]:
    """Column batches ``(ts, et, name, size, tag)`` of one rank's stream in
    time order — wrapper events included.  ``size`` is NaN on non-message
    rows; every message row is an ``MpiSend`` instant to rank ``p+1``."""
    rng = np.random.default_rng(seed * 100_003 + p)
    # rows per leaf call: 2 (enter/leave); every 8th call adds a message
    # instant; each iteration adds 2 wrapper rows.  Solve for leaf count.
    rows_per_call = 2 + 1 / 8
    n_iters = max(1, int((events_per_proc - 2)
                         / (calls_per_iter * rows_per_call + 2)))
    t = 0
    yield _single(t, _ENTER, _MAIN)
    for it in range(n_iters):
        yield _single(t, _ENTER, _ITER)
        done = 0
        while done < calls_per_iter:
            k = min(batch_calls, calls_per_iter - done)
            batch, t = _leaf_batch(rng, t, k, it)
            yield batch
            done += k
        t += 2 * _US
        yield _single(t, _LEAVE, _ITER)
    t += 5 * _US
    yield _single(t, _LEAVE, _MAIN)


def _single(t: int, et: int, name: int) -> Tuple[np.ndarray, ...]:
    return (np.asarray([t], np.int64), np.asarray([et], np.int8),
            np.asarray([name], np.int32), np.asarray([np.nan]),
            np.asarray([0], np.int64))


def _leaf_batch(rng, t: int, k: int, tag: int) -> Tuple[Tuple[np.ndarray, ...], int]:
    """k leaf calls (plus their message instants) as interleaved column
    arrays, in time order."""
    durs = rng.integers(5 * _US, 40 * _US, size=k)
    which = rng.integers(0, len(_LEAF_NAMES), size=k)
    starts = t + np.concatenate([[0], np.cumsum(durs[:-1])])
    ends = starts + durs
    msg_at = np.arange(k) % 8 == 7  # every 8th call sends
    sizes = rng.integers(256, 8192, size=k)
    n_msg = int(msg_at.sum())
    n = 2 * k + n_msg
    ts = np.empty(n, np.int64)
    et = np.empty(n, np.int8)
    name = np.empty(n, np.int32)
    size = np.full(n, np.nan)
    tags = np.zeros(n, np.int64)
    # row position of each call's enter: 2 rows per call + 1 per earlier msg
    msg_before = np.concatenate([[0], np.cumsum(msg_at[:-1])])
    pos = 2 * np.arange(k) + msg_before
    ts[pos] = starts
    et[pos] = _ENTER
    name[pos] = np.asarray(_LEAF_NAMES, np.int32)[which]
    leave_pos = pos + 1 + msg_at  # message instant (if any) sits between
    ts[leave_pos] = ends
    et[leave_pos] = _LEAVE
    name[leave_pos] = np.asarray(_LEAF_NAMES, np.int32)[which]
    mpos = pos[msg_at] + 1
    ts[mpos] = (starts[msg_at] + ends[msg_at]) // 2
    et[mpos] = _INSTANT
    name[mpos] = _MPISEND
    size[mpos] = sizes[msg_at]
    tags[mpos] = tag
    return (ts, et, name, size, tags), int(ends[-1]) if k else t


# ---------------------------------------------------------------------------
# format-specific serialization
# ---------------------------------------------------------------------------

_ET_STR = ("Enter", "Leave", "Instant")


def _write_rank_jsonl(path: str, p: int, nprocs: int, events_per_proc: int,
                      calls_per_iter: int, seed: int,
                      batch_calls: int) -> None:
    dst = (p + 1) % nprocs
    with open(path, "w") as f:
        for ts, et, name, size, tag in _rank_batches(
                p, nprocs, events_per_proc, calls_per_iter, seed,
                batch_calls):
            lines = []
            for i in range(len(ts)):
                if et[i] == _INSTANT:
                    lines.append(
                        f'{{"ts":{ts[i]},"et":"Instant",'
                        f'"name":"{_NAMES[name[i]]}","proc":{p},'
                        f'"partner":{dst},"size":{int(size[i])},'
                        f'"tag":{tag[i]}}}\n')
                else:
                    lines.append(
                        f'{{"ts":{ts[i]},"et":"{_ET_STR[et[i]]}",'
                        f'"name":"{_NAMES[name[i]]}","proc":{p}}}\n')
            f.writelines(lines)


def _write_rank_pack(path: str, p: int, nprocs: int, events_per_proc: int,
                     calls_per_iter: int, seed: int,
                     batch_calls: int) -> None:
    from ..core.constants import (ET, MSG_SIZE, NAME, PARTNER, PROC, TAG, TS)
    from ..core.frame import Categorical, EventFrame
    from ..readers.pack import PackWriter
    dst = (p + 1) % nprocs
    cats = np.asarray(_NAMES, dtype=object).astype(str)
    et_cats = np.asarray(_ET_STR)
    # in-place (non-atomic) write: a killed generator leaves finalized chunk
    # groups at the destination, exactly what salvage / --repair recover —
    # the crash-consistency smoke in CI depends on this
    with PackWriter(path, atomic=False) as w:
        for ts, et, name, size, tag in _rank_batches(
                p, nprocs, events_per_proc, calls_per_iter, seed,
                batch_calls):
            n = len(ts)
            partner = np.where(np.isnan(size), -1, dst).astype(np.int64)
            w.append(EventFrame({
                TS: ts,
                ET: Categorical(et.astype(np.int32), et_cats),
                NAME: Categorical(name, cats),
                PROC: np.full(n, p, np.int64),
                MSG_SIZE: size,
                PARTNER: partner,
                TAG: np.where(partner >= 0, tag, 0),
            }))
        w.finish(sidecar=True)
