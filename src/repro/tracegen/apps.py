"""Application-shaped trace generators (see package docstring).

Every generator takes a ``perturb`` knob: a mapping from function name to a
duration multiplier applied at generation time (``{"computeRhs": 1.5}``
makes every computeRhs call 50% slower, shifting downstream events on the
same timeline consistently).  Generating the same app twice — once without
and once with a perturbation — yields a "before/after" pair whose only
injected difference is known, which is exactly what the TraceDiff subsystem
(:mod:`repro.core.diff`) needs for regression-hunting tests and benchmarks;
:func:`regression_pair` packages that recipe.
"""

from __future__ import annotations

from typing import Mapping, Optional, Tuple

import numpy as np

from ..core.trace import Trace
from .builder import TraceBuilder

__all__ = ["gol", "stencil3d", "amg_vcycle", "kripke_sweep", "tortuga",
           "loimos", "axonn_training", "regression_pair"]

_US = 1_000.0          # 1 microsecond in ns
_MS = 1_000_000.0      # 1 millisecond in ns

Perturb = Optional[Mapping[str, float]]


def _pfac(perturb: Perturb, name: str) -> float:
    """Duration multiplier the perturbation knob assigns to ``name``."""
    return float(perturb.get(name, 1.0)) if perturb else 1.0


def gol(nprocs: int = 4, iters: int = 10, rows_per_proc: int = 512,
        imbalance: float = 0.3, seed: int = 0, perturb: Perturb = None) -> Trace:
    """1-D row-decomposed Game of Life: compute + halo exchange with ring
    neighbors. Process 0 gets `imbalance` extra work so it drags the critical
    path through its sends (paper Fig. 10/11 structure)."""
    rng = np.random.default_rng(seed)
    b = TraceBuilder()
    halo_bytes = rows_per_proc * 8.0
    # per-process clocks; blocking semantics enforced by recv-after-send times
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "main()", p)
    send_done = np.zeros((iters, nprocs))  # time each proc's send completes
    for it in range(iters):
        b_tag = it
        for p in range(nprocs):
            t = clocks[p]
            work = 200 * _US * (1.0 + (imbalance if p == 0 else 0.0)
                                + 0.05 * rng.standard_normal())
            work *= _pfac(perturb, "compute_cells()")
            t = b.call(t, max(work, _US), "compute_cells()", p)
            nbr = (p + 1) % nprocs
            t = b.send(t, 5 * _US, p, nbr, halo_bytes, tag=b_tag)
            send_done[it, p] = t
            clocks[p] = t
        for p in range(nprocs):
            src = (p - 1) % nprocs
            t0 = clocks[p]
            arrive = send_done[it, src] + 2 * _US  # network latency
            t1 = max(t0, arrive) + 3 * _US
            b.recv(t0, t1 - t0, p, src, halo_bytes, tag=b_tag)
            clocks[p] = t1
    end = clocks.max() + 10 * _US
    for p in range(nprocs):
        b.leave(end if p == 0 else clocks[p] + 5 * _US, "main()", p)
    return b.trace(label=f"gol_{nprocs}")


def stencil3d(nprocs: int = 32, iters: int = 5, side_bytes: float = 6750.0,
              seed: int = 0, perturb: Perturb = None) -> Trace:
    """3-D near-neighbor exchange on a virtual processor grid — produces the
    banded, symmetric comm matrix of Fig. 3 (Laghos) with three message-size
    clusters (corner/edge/face)."""
    rng = np.random.default_rng(seed)
    # factor nprocs into a 3-d grid
    dims = _balanced_dims(nprocs, 3)
    coords = np.array(np.unravel_index(np.arange(nprocs), dims)).T
    b = TraceBuilder()
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "main()", p)
    for it in range(iters):
        for p in range(nprocs):
            t = clocks[p]
            t = b.call(t, (300 + 30 * rng.standard_normal()) * _US
                       * _pfac(perturb, "kernel_update()"),
                       "kernel_update()", p)
            c = coords[p]
            for axis in range(3):
                for d in (-1, 1):
                    nc = c.copy()
                    nc[axis] += d
                    if (nc < 0).any() or (nc >= np.array(dims)).any():
                        continue
                    q = int(np.ravel_multi_index(nc, dims))
                    nbytes = side_bytes * 2 if axis == 0 else (
                        side_bytes if axis == 1 else side_bytes / 5.0)
                    t = b.send(t, 4 * _US, p, q, nbytes, tag=it)
                    t = b.recv(t, 6 * _US, p, q, nbytes, tag=it)
            clocks[p] = t
    for p in range(nprocs):
        b.leave(clocks[p] + 5 * _US, "main()", p)
    return b.trace(label=f"stencil3d_{nprocs}")


def amg_vcycle(nprocs: int = 16, iters: int = 4, levels: int = 4,
               fine_bytes: float = 13500.0, seed: int = 0,
               perturb: Perturb = None) -> Trace:
    """Algebraic-multigrid V-cycle: per level, smooth + neighbor exchange with
    message sizes shrinking 4× per level, plus an all-reduce (norm check) at
    the coarsest level (AMG trace structure of Fig. 5)."""
    rng = np.random.default_rng(seed)
    b = TraceBuilder()
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "main()", p)
    for it in range(iters):
        for direction, levs in (("down", range(levels)),
                                ("up", range(levels - 2, -1, -1))):
            for lev in levs:
                sz = fine_bytes / (4.0 ** lev)
                for p in range(nprocs):
                    t = clocks[p]
                    t = b.call(t, (120 / (2.0 ** lev)
                                   + 8 * rng.standard_normal()) * _US
                               * _pfac(perturb, f"smooth_l{lev}()"),
                               f"smooth_l{lev}()", p)
                    for q in (p - 1, p + 1):
                        if 0 <= q < nprocs:
                            t = b.send(t, 3 * _US, p, q, sz, tag=lev)
                            t = b.recv(t, 4 * _US, p, q, sz, tag=lev)
                    clocks[p] = t
        # coarse-level all-reduce: model as send to 0 + broadcast back
        tmax = clocks.max()
        for p in range(nprocs):
            t = max(clocks[p], tmax)
            t = b.call(t, 15 * _US * _pfac(perturb, "MPI_Allreduce"),
                       "MPI_Allreduce", p)
            clocks[p] = t
    for p in range(nprocs):
        b.leave(clocks[p] + 5 * _US, "main()", p)
    return b.trace(label=f"amg_{nprocs}")


def kripke_sweep(nprocs: int = 16, iters: int = 3, cell_bytes: float = 4096.0,
                 seed: int = 0, perturb: Perturb = None) -> Trace:
    """Wavefront sweep: proc p's work in each sweep depends on p-1's send —
    a long dependency chain that dominates the critical path (Kripke)."""
    rng = np.random.default_rng(seed)
    b = TraceBuilder()
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "main()", p)
    for it in range(iters):
        # downward sweep 0→n-1 then upward n-1→0
        for order in (range(nprocs), range(nprocs - 1, -1, -1)):
            order = list(order)
            upstream_done = 0.0
            for i, p in enumerate(order):
                t = clocks[p]
                if i > 0:
                    src = order[i - 1]
                    t0 = t
                    t = max(t, upstream_done + 2 * _US) + 4 * _US
                    b.recv(t0, t - t0, p, src, cell_bytes, tag=it)
                t = b.call(t, (150 + 10 * rng.standard_normal()) * _US
                           * _pfac(perturb, "sweep_cells()"),
                           "sweep_cells()", p)
                if i < len(order) - 1:
                    t = b.send(t, 3 * _US, p, order[i + 1], cell_bytes, tag=it)
                    upstream_done = t
                clocks[p] = t
    for p in range(nprocs):
        b.leave(clocks[p] + 5 * _US, "main()", p)
    return b.trace(label=f"kripke_{nprocs}")


def tortuga(nprocs: int = 16, iters: int = 6, scaling_knee: int = 32,
            seed: int = 0, perturb: Perturb = None) -> Trace:
    """CFD iteration with the Fig. 12 function mix.  Past ``scaling_knee``
    processes, per-process work stops shrinking (surface-to-volume effect), so
    total time across the multirun study rises — reproducing the paper's
    'computeRhs/gradC2C scale poorly' finding.  Every iteration is wrapped in
    a ``time-loop`` marker for pattern detection (Fig. 8)."""
    rng = np.random.default_rng(seed)
    b = TraceBuilder()
    # per-process work: ideal scaling up to the knee, then saturates
    eff = min(nprocs, scaling_knee)
    base = 4000.0 / eff * _US          # computeRhs per-proc cost
    ghost_bytes = 6750.0 * (1.0 + nprocs / 64.0)
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "main()", p)
    for it in range(iters):
        tl_start = clocks.copy()
        for p in range(nprocs):
            b.enter(clocks[p], "time-loop", p)
        send_done = np.zeros(nprocs)
        for p in range(nprocs):
            t = clocks[p]
            t = b.call(t, base * (1 + 0.04 * rng.standard_normal())
                       * _pfac(perturb, "computeRhs"), "computeRhs", p)
            t = b.call(t, base * 0.22 * (1 + 0.05 * rng.standard_normal())
                       * _pfac(perturb, "gradC2C"), "gradC2C", p)
            t = b.call(t, base * 0.06
                       * _pfac(perturb, "setGhostCvsInterfaces"),
                       "setGhostCvsInterfaces", p)
            for q in (p - 1, p + 1):
                if 0 <= q < nprocs:
                    t = b.send(t, 3 * _US, p, q, ghost_bytes, tag=it,
                               name="MPI_Isend")
            send_done[p] = t
            clocks[p] = t
        for p in range(nprocs):
            t = clocks[p]
            nbrs = [q for q in (p - 1, p + 1) if 0 <= q < nprocs]
            arrive = max(send_done[q] for q in nbrs) + 2 * _US
            t_wait_end = max(t, arrive) + 2 * _US
            b.enter(t, "MPI_Wait", p)
            for q in nbrs:
                b.event(t + _US, "MpiRecv", "MpiRecv", p, partner=q,
                        size=ghost_bytes, tag=it)
            b.leave(t_wait_end, "MPI_Wait", p)
            t = b.call(t_wait_end, base * 0.065
                       * _pfac(perturb, "endGhostCvsInterfaces"),
                       "endGhostCvsInterfaces", p)
            b.leave(t, "time-loop", p)
            clocks[p] = t
    for p in range(nprocs):
        b.leave(clocks[p] + 5 * _US, "main()", p)
    return b.trace(label=f"tortuga_{nprocs}")


def loimos(nprocs: int = 128, iters: int = 4, seed: int = 0,
           hot_procs=(21, 22, 23, 24, 29), perturb: Perturb = None) -> Trace:
    """Actor-style epidemic simulation: ComputeInteractions / SendVisitMessages
    / ReceiveVisitMessages with a hot subset of processes carrying 2-3× load
    (Fig. 7 structure), plus explicit Idle spans."""
    rng = np.random.default_rng(seed)
    hot = set(q for q in hot_procs if q < nprocs)
    b = TraceBuilder()
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "main()", p)
    for it in range(iters):
        for p in range(nprocs):
            t = clocks[p]
            boost = 2.2 if p in hot else 1.0
            t = b.call(t, 90 * boost * (1 + .1 * rng.standard_normal()) * _US
                       * _pfac(perturb, "ComputeInteractions()"),
                       "ComputeInteractions()", p)
            dst = int(rng.integers(0, nprocs))
            b.enter(t, "SendVisitMessages()", p)
            b.event(t + 2 * _US, "MpiSend", "MpiSend", p, partner=dst,
                    size=float(rng.integers(256, 4096)), tag=it)
            t += 60 * boost * 0.8 * _US * _pfac(perturb, "SendVisitMessages()")
            b.leave(t, "SendVisitMessages()", p)
            t = b.call(t, 70 * boost * (1 + .1 * rng.standard_normal()) * _US
                       * _pfac(perturb, "ReceiveVisitMessages(const VisitMessage &impl_noname_1)"),
                       "ReceiveVisitMessages(const VisitMessage &impl_noname_1)", p)
            # under-loaded procs idle while hot procs finish
            idle = (180.0 * (2.2 - boost) + 20 * abs(rng.standard_normal())) * _US
            t = b.call(t, idle * _pfac(perturb, "Idle"), "Idle", p)
            clocks[p] = t
    for p in range(nprocs):
        b.leave(clocks[p] + 5 * _US, "main()", p)
    return b.trace(label=f"loimos_{nprocs}")


def axonn_training(nprocs: int = 8, iters: int = 8, version: int = 0,
                   seed: int = 0, perturb: Perturb = None) -> Trace:
    """Data/tensor-parallel training iterations at three optimization levels
    (Fig. 13):

    * v0 — big blocking all-reduce after backward (no overlap, extra transpose
      comm),
    * v1 — transposed layouts remove half the communication volume,
    * v2 — remaining all-reduce bucketed and overlapped with backward compute
      on a second stream (thread 1).
    """
    rng = np.random.default_rng(seed)
    b = TraceBuilder(with_threads=True)
    comm_scale = {0: 1.0, 1: 0.45, 2: 0.45}[version]
    overlap = version == 2
    clocks = np.zeros(nprocs)
    for p in range(nprocs):
        b.enter(0.0, "train()", p, 0)
    grad_bytes = 25e6 * comm_scale
    for it in range(iters):
        for p in range(nprocs):
            t = clocks[p]
            t = b.call(t, (900 + 25 * rng.standard_normal()) * _US
                       * _pfac(perturb, "forward"), "forward", p, 0)
            bwd = (1800 + 40 * rng.standard_normal()) * _US \
                * _pfac(perturb, "backward")
            if overlap:
                # backward on stream 0; bucketed all-reduce on stream 1
                b.enter(t, "backward", p, 0)
                tb = t
                n_buckets = 4
                for k in range(n_buckets):
                    tc = t + bwd * (k + 0.5) / n_buckets
                    dst = (p + 1) % nprocs
                    b.enter(tc, "ncclAllReduce", p, 1)
                    b.event(tc + 2 * _US, "MpiSend", "MpiSend", p, 1,
                            partner=dst, size=grad_bytes / n_buckets, tag=k)
                    b.event(tc + 3 * _US, "MpiRecv", "MpiRecv", p, 1,
                            partner=(p - 1) % nprocs, size=grad_bytes / n_buckets,
                            tag=k)
                    b.leave(tc + bwd / n_buckets * 0.7, "ncclAllReduce", p, 1)
                t = tb + bwd
                b.leave(t, "backward", p, 0)
                t = b.call(t, (250 + comm_scale * 120) * _US, "ncclAllReduce", p, 0)
            else:
                t = b.call(t, bwd, "backward", p, 0)
                dur = (900 * comm_scale + 420) * _US
                dst = (p + 1) % nprocs
                b.enter(t, "ncclAllReduce", p, 0)
                b.event(t + 3 * _US, "MpiSend", "MpiSend", p, 0, partner=dst,
                        size=grad_bytes, tag=it)
                b.event(t + 5 * _US, "MpiRecv", "MpiRecv", p, 0,
                        partner=(p - 1) % nprocs, size=grad_bytes, tag=it)
                b.leave(t + dur, "ncclAllReduce", p, 0)
                t += dur
            t = b.call(t, 120 * _US * _pfac(perturb, "optimizer_step"),
                       "optimizer_step", p, 0)
            clocks[p] = t
    for p in range(nprocs):
        b.leave(clocks[p] + 5 * _US, "train()", p, 0)
    return b.trace(label=f"axonn_v{version}_{nprocs}")


_APPS = {
    "gol": gol, "stencil3d": stencil3d, "amg_vcycle": amg_vcycle,
    "kripke_sweep": kripke_sweep, "tortuga": tortuga, "loimos": loimos,
    "axonn_training": axonn_training,
}


def regression_pair(app: str = "tortuga", func: str = "computeRhs",
                    factor: float = 1.5, seed: int = 0,
                    **kw) -> Tuple[Trace, Trace]:
    """A "before/after" trace pair with one *known* injected regression.

    Generates ``app`` twice with the same seed — identical except that in
    the "after" run every call of ``func`` is slowed by ``factor`` (the
    ``perturb`` knob), so downstream timestamps shift consistently while
    every other function's own durations stay bit-identical.  The pair is
    the ground truth the TraceDiff subsystem's ``regression_report`` is
    tested and benchmarked against: its top-ranked function must be
    ``func``.

    Args:
        app: generator name (one of gol, stencil3d, amg_vcycle,
            kripke_sweep, tortuga, loimos, axonn_training).
        func: exact event name to slow down (as emitted by the generator,
            e.g. ``"compute_cells()"`` for gol).
        factor: duration multiplier for the "after" run (> 1 = regression,
            < 1 = improvement).
        **kw: forwarded to the generator (nprocs, iters, ...).

    Returns:
        ``(before, after)`` traces labeled ``<app>-before`` / ``<app>-after``.
    """
    try:
        gen = _APPS[app]
    except KeyError:
        raise ValueError(f"unknown app {app!r}; one of {sorted(_APPS)}") \
            from None
    before = gen(seed=seed, **kw)
    after = gen(seed=seed, perturb={func: factor}, **kw)
    before.label = f"{app}-before"
    after.label = f"{app}-after"
    return before, after


def _balanced_dims(n: int, k: int):
    """Factor n into k near-equal dims (largest first)."""
    dims = [1] * k
    rem = n
    for i in range(k):
        d = int(round(rem ** (1.0 / (k - i))))
        while d > 1 and rem % d:
            d -= 1
        dims[i] = max(d, 1)
        rem //= dims[i]
    dims[0] *= rem
    return tuple(sorted(dims, reverse=True))
