"""Minimal vendored property-testing fallback with a hypothesis-shaped API.

Implements exactly the subset this repo's test suites use — ``given``,
``settings``, and the ``strategies`` constructors ``integers``, ``floats``,
``booleans``, ``sampled_from``, ``lists``, ``composite`` — on top of a
seeded ``numpy.random.Generator``.  No shrinking, no database, no health
checks: on failure the raising example's seed and draw log are printed so
the case can be reproduced by re-running the test (generation is
deterministic per test name).

Import through :mod:`repro.testing.hyp`, which prefers the real hypothesis
whenever it is installed.
"""

from __future__ import annotations

import functools
import inspect
import zlib
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_EXAMPLES = 100


class Strategy:
    """A value generator: ``do_draw(rng)`` produces one example."""

    def __init__(self, draw_fn: Callable[[np.random.Generator], Any],
                 label: str = "strategy"):
        self._draw = draw_fn
        self.label = label

    def do_draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self.do_draw(rng)),
                        f"{self.label}.map")

    def __repr__(self) -> str:  # pragma: no cover
        return f"Strategy<{self.label}>"


class _Strategies:
    """The ``strategies`` namespace (imported as ``st``)."""

    @staticmethod
    def integers(min_value: int = -(2 ** 31), max_value: int = 2 ** 31 - 1
                 ) -> Strategy:
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value},{max_value})")

    @staticmethod
    def floats(min_value: float = -1e9, max_value: float = 1e9,
               allow_nan: bool = False, allow_infinity: bool = False,
               width: int = 64) -> Strategy:
        def draw(rng: np.random.Generator) -> float:
            if allow_nan and rng.random() < 0.02:
                return float("nan")
            if allow_infinity and rng.random() < 0.02:
                return float(np.inf if rng.random() < 0.5 else -np.inf)
            # mix uniform draws with boundary values — property tests live
            # on the edges
            r = rng.random()
            if r < 0.05:
                return float(min_value)
            if r < 0.1:
                return float(max_value)
            if r < 0.15 and min_value <= 0.0 <= max_value:
                return 0.0
            return float(rng.uniform(min_value, max_value))
        return Strategy(draw, f"floats({min_value},{max_value})")

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")

    @staticmethod
    def sampled_from(elements: Sequence) -> Strategy:
        elements = list(elements)
        return Strategy(lambda rng: elements[int(rng.integers(len(elements)))],
                        f"sampled_from({len(elements)})")

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: Optional[int] = None) -> Strategy:
        cap = max_size if max_size is not None else min_size + 20

        def draw(rng: np.random.Generator) -> List:
            n = int(rng.integers(min_size, cap + 1))
            return [elements.do_draw(rng) for _ in range(n)]
        return Strategy(draw, f"lists[{min_size},{cap}]")

    @staticmethod
    def composite(fn: Callable) -> Callable[..., Strategy]:
        """``@st.composite`` — ``fn(draw, *args)`` builds one example."""

        @functools.wraps(fn)
        def factory(*args: Any, **kwargs: Any) -> Strategy:
            def draw_one(rng: np.random.Generator):
                def draw(strategy: Strategy):
                    return strategy.do_draw(rng)
                return fn(draw, *args, **kwargs)
            return Strategy(draw_one, f"composite:{fn.__name__}")
        return factory


strategies = _Strategies()


class HealthCheck:  # pragma: no cover - API-compat shell
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    all = staticmethod(lambda: [])


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
             **_ignored) -> Callable:
    """Decorator recording run parameters; composes with :func:`given` in
    either order, like the real library."""

    def deco(fn: Callable) -> Callable:
        fn._minihyp_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: Strategy, **kw_strats: Strategy) -> Callable:
    """Run the test once per generated example (seeded per test name, so
    failures reproduce deterministically)."""

    def deco(fn: Callable) -> Callable:
        conf = getattr(fn, "_minihyp_settings", None)

        @functools.wraps(fn)
        def runner(*outer_args: Any, **outer_kwargs: Any) -> None:
            n = (conf or getattr(runner, "_minihyp_settings", None)
                 or {"max_examples": _DEFAULT_EXAMPLES})["max_examples"]
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                args = [s.do_draw(rng) for s in strats]
                kwargs = {k: s.do_draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*outer_args, *args, **outer_kwargs, **kwargs)
                except Exception:
                    print(f"minihyp: falsifying example #{i} "
                          f"(seed={seed}) for {fn.__qualname__}: "
                          f"args={args!r} kwargs={kwargs!r}")
                    raise

        # strategy-bound parameters must not look like pytest fixtures:
        # expose the signature with the bound ones removed (positional
        # strategies bind to the rightmost params, like hypothesis)
        params = list(inspect.signature(fn).parameters.values())
        if strats:
            params = params[: len(params) - len(strats)]
        params = [p for p in params if p.name not in kw_strats]
        runner.__signature__ = inspect.Signature(params)
        del runner.__wrapped__
        return runner
    return deco
