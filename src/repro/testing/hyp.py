"""Property-testing front door: the real hypothesis when installed, the
vendored :mod:`repro.testing.minihyp` fallback otherwise.

Use in tests as::

    from repro.testing.hyp import given, settings, st

so the suites run (not skip) in dependency-free environments and get full
shrinking/replay power wherever the ``dev`` extra is installed.
"""

from __future__ import annotations

try:  # pragma: no cover - depends on environment
    from hypothesis import HealthCheck, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    from .minihyp import HealthCheck, given, settings  # noqa: F401
    from .minihyp import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = False

__all__ = ["given", "settings", "st", "HealthCheck", "HAVE_HYPOTHESIS"]
