"""Fault injection for trace I/O robustness testing.

Two layers of deterministic, closed-loop injectors:

**File-level** — damage a *copy* of a trace file in a precisely known
way, so tests can assert the reader's contract (strict = loud failure
naming the file and locus; salvage/skip = survivors intact, losses
counted) against ground truth:

* :func:`truncate_at` — cut the file at a byte offset or fraction
  (simulates a crash mid-write or a short download);
* :func:`bit_flip` — flip bits at seeded-random or explicit offsets
  (simulates silent media corruption; trips pack CRCs);
* :func:`garbage_append` — append seeded-random bytes (simulates a torn
  append or concatenated partial write);
* :func:`torn_footer` — pack-specific: sever the footer mid-blob, the
  exact shape a SIGKILL during footer write leaves behind.

**Service-level** — inject transport and open failures around the
trace-query service:

* :class:`FaultProxy` — a byte-pumping TCP proxy between client and
  server with programmable connection resets (including *mid-response*)
  and fixed delays, with counters for closed-loop assertions;
* :func:`flaky_opens` — make the service's handle opens fail a chosen
  number of times (drives the circuit breaker without corrupt files).

Everything here is stdlib-only and deterministic (seeded RNG, counted
faults) — injectors never touch the original file and never depend on
timing to decide *whether* a fault fires.
"""

from __future__ import annotations

import contextlib
import os
import random
import shutil
import socket
import struct
import threading
import time
from typing import Iterator, Optional

__all__ = ["truncate_at", "bit_flip", "garbage_append", "torn_footer",
           "FaultProxy", "flaky_opens"]


# ---------------------------------------------------------------------------
# file-level injectors
# ---------------------------------------------------------------------------

def _copy(src: str, dst: str) -> int:
    src, dst = os.fspath(src), os.fspath(dst)
    if os.path.abspath(src) != os.path.abspath(dst):
        shutil.copyfile(src, dst)  # src == dst damages in place
    return os.path.getsize(dst)


def truncate_at(src: str, dst: str, *, offset: Optional[int] = None,
                frac: Optional[float] = None) -> dict:
    """Copy ``src`` to ``dst`` truncated at ``offset`` bytes (or at
    ``frac`` of the original size).  ``frac=0.0`` produces an empty file,
    ``frac=0.99`` a file missing its tail — both are distinct reader
    pathologies.  Returns ``{"size", "cut_at", "lost"}``."""
    size = _copy(src, dst)
    if offset is None:
        if frac is None:
            raise ValueError("truncate_at needs offset= or frac=")
        offset = int(size * float(frac))
    offset = max(0, min(int(offset), size))
    with open(dst, "r+b") as f:
        f.truncate(offset)
    return {"size": size, "cut_at": offset, "lost": size - offset}


def bit_flip(src: str, dst: str, *, offsets: Optional[list] = None,
             frac: float = 0.5, count: int = 1, seed: int = 0) -> dict:
    """Copy ``src`` to ``dst`` with ``count`` single-bit flips.  Explicit
    ``offsets`` pin the damage; otherwise offsets are drawn from a seeded
    RNG centred on ``frac`` of the file (body damage by default — pass
    ``frac`` near 1.0 to hit index/footer regions).  Returns the exact
    flipped offsets so tests can assert which chunk/record was hit."""
    size = _copy(src, dst)
    if size == 0:
        raise ValueError(f"{src}: cannot bit-flip an empty file")
    rng = random.Random(seed)
    if offsets is None:
        lo = int(size * max(0.0, float(frac) - 0.25))
        hi = max(lo + 1, int(size * min(1.0, float(frac) + 0.25)))
        offsets = [rng.randrange(lo, min(hi, size)) for _ in range(count)]
    offsets = [int(o) % size for o in offsets]
    with open(dst, "r+b") as f:
        for off in offsets:
            f.seek(off)
            b = f.read(1)[0]
            f.seek(off)
            f.write(bytes([b ^ (1 << rng.randrange(8))]))
    return {"size": size, "offsets": sorted(offsets)}


def garbage_append(src: str, dst: str, *, nbytes: int = 256,
                   seed: int = 0) -> dict:
    """Copy ``src`` to ``dst`` and append ``nbytes`` of seeded-random
    garbage — a torn concurrent append / partially-flushed next record."""
    size = _copy(src, dst)
    rng = random.Random(seed)
    with open(dst, "ab") as f:
        f.write(bytes(rng.randrange(256) for _ in range(int(nbytes))))
    return {"size": size, "appended": int(nbytes)}


def torn_footer(src: str, dst: str, *, keep_frac: float = 0.5) -> dict:
    """Copy a **pack** to ``dst`` with its footer torn: the trailing
    ``(blob, <Q length>, tail magic)`` triplet is cut mid-blob (keeping
    ``keep_frac`` of it), exactly what a SIGKILL between the last chunk
    group and a completed footer write leaves on disk.  Falls back to
    chopping the final 25% of a non-pack file.  The chunk groups remain
    intact, so salvage must recover every row."""
    size = _copy(src, dst)
    cut = None
    if size >= 16:
        with open(dst, "rb") as f:
            f.seek(size - 16)
            flen = struct.unpack("<Q", f.read(8))[0]
            tail = f.read(8)
        if tail == b"PIPITPK\x00" and flen <= size - 16:
            foot_start = size - 16 - flen
            cut = foot_start + int(flen * float(keep_frac))
    if cut is None:
        cut = int(size * 0.75)
    with open(dst, "r+b") as f:
        f.truncate(cut)
    return {"size": size, "cut_at": cut, "lost": size - cut}


# ---------------------------------------------------------------------------
# service-level injectors
# ---------------------------------------------------------------------------

class FaultProxy:
    """A TCP proxy that injects transport faults between a client and the
    trace-query server.

    Faults are decided per *HTTP request* (request starts are recognized
    in the client byte stream, so keep-alive connections carrying many
    requests are faulted correctly), counted from 1 across the proxy's
    lifetime:

    * ``reset_every=k`` — every k-th request is answered with a hard
      connection reset (``SO_LINGER`` 0 → RST) instead of a response;
    * ``reset_after_bytes=n`` — a doomed request additionally forwards
      the first ``n`` bytes of the server's real response before the
      reset: the *mid-response* reset a retrying client must survive
      (the server **did** execute the request).  ``n=0`` (default)
      resets before the request even reaches the server — a pure
      transport fault;
    * ``delay=s`` — sleep ``s`` seconds before pumping each response
      batch (drives client/service deadline paths without slow ops).

    ``stats`` counts ``connections``, ``requests`` and ``resets`` so
    tests close the loop on exactly how many faults fired.
    Deterministic: whether a request is faulted depends only on its
    sequence number.
    """

    _METHODS = (b"GET ", b"POST", b"PUT ", b"HEAD", b"DELE", b"PATC",
                b"OPTI")

    def __init__(self, upstream_host: str, upstream_port: int, *,
                 reset_every: int = 0, reset_after_bytes: int = 0,
                 delay: float = 0.0):
        self.upstream = (upstream_host, int(upstream_port))
        self.reset_every = int(reset_every)
        self.reset_after_bytes = int(reset_after_bytes)
        self.delay = float(delay)
        self.stats = {"connections": 0, "requests": 0, "resets": 0}
        self._count_lock = threading.Lock()
        self._srv: Optional[socket.socket] = None
        self._threads: list = []
        self._stop = threading.Event()
        self.port: Optional[int] = None

    # -- lifecycle --------------------------------------------------------
    def start(self) -> int:
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(32)
        self._srv.settimeout(0.2)
        self.port = self._srv.getsockname()[1]
        t = threading.Thread(target=self._accept_loop,
                             name="faultproxy-accept", daemon=True)
        t.start()
        self._threads.append(t)
        return self.port

    def stop(self) -> None:
        self._stop.set()
        if self._srv is not None:
            with contextlib.suppress(OSError):
                self._srv.close()
        for t in self._threads:
            t.join(timeout=2.0)

    def __enter__(self) -> "FaultProxy":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- internals --------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                cli, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            self.stats["connections"] += 1
            idx = self.stats["connections"]
            t = threading.Thread(target=self._serve, args=(cli,),
                                 name=f"faultproxy-conn-{idx}", daemon=True)
            t.start()
            self._threads.append(t)

    @staticmethod
    def _abort(sock: socket.socket) -> None:
        """Hard-abort: RST instead of FIN, so the peer sees a genuine
        connection reset rather than a clean close."""
        with contextlib.suppress(OSError):
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                            struct.pack("ii", 1, 0))
        # a sibling pump thread may be blocked in recv() on this socket;
        # close() alone would defer teardown (the syscall pins the fd) and
        # the RST would never be sent — SHUT_RD wakes it first
        with contextlib.suppress(OSError):
            sock.shutdown(socket.SHUT_RD)
        with contextlib.suppress(OSError):
            sock.close()

    def _next_request_doomed(self) -> bool:
        with self._count_lock:
            self.stats["requests"] += 1
            n = self.stats["requests"]
        return bool(self.reset_every) and n % self.reset_every == 0

    def _serve(self, cli: socket.socket) -> None:
        try:
            up = socket.create_connection(self.upstream, timeout=10.0)
        except OSError:
            self._abort(cli)
            return
        # response-byte budget for the currently-doomed request; None when
        # the in-flight request is healthy.  Keep-alive requests are
        # sequential, so one slot per connection is enough.
        budget = [None]

        def reset():
            self.stats["resets"] += 1
            self._abort(cli)
            self._abort(up)

        def pump_requests():
            try:
                while not self._stop.is_set():
                    data = cli.recv(65536)
                    if not data:
                        break
                    if data[:4] in self._METHODS:
                        if self._next_request_doomed():
                            if self.reset_after_bytes <= 0:
                                # pure transport fault: the server never
                                # sees the request
                                reset()
                                return
                            budget[0] = self.reset_after_bytes
                        else:
                            budget[0] = None
                    up.sendall(data)
            except OSError:
                pass
            finally:
                with contextlib.suppress(OSError):
                    up.shutdown(socket.SHUT_WR)

        def pump_responses():
            try:
                while not self._stop.is_set():
                    data = up.recv(65536)
                    if not data:
                        break
                    if self.delay:
                        time.sleep(self.delay)
                    if budget[0] is not None:
                        cli.sendall(data[:max(budget[0], 0)])
                        budget[0] -= len(data)
                        if budget[0] <= 0:
                            # mid-response reset: part of the real
                            # response escaped, the rest never will
                            reset()
                            return
                    else:
                        cli.sendall(data)
            except OSError:
                pass
            finally:
                with contextlib.suppress(OSError):
                    cli.shutdown(socket.SHUT_WR)

        tr = threading.Thread(target=pump_requests, daemon=True)
        tr.start()
        pump_responses()
        tr.join(timeout=5.0)
        for s in (cli, up):
            with contextlib.suppress(OSError):
                s.close()


@contextlib.contextmanager
def flaky_opens(times: int, exc: Optional[Exception] = None
                ) -> Iterator[dict]:
    """Make :class:`~repro.serving.tracequery.HandlePool` opens fail the
    first ``times`` calls with ``exc`` (default ``OSError``), then behave
    normally — the deterministic driver for circuit-breaker tests that
    does not require an actually-corrupt file.  Yields a counter dict
    (``{"calls", "failed"}``); restores the original open on exit."""
    from ..serving.tracequery import HandlePool
    counter = {"calls": 0, "failed": 0}
    orig = HandlePool._open

    def _failing(self, spec):
        counter["calls"] += 1
        if counter["failed"] < times:
            counter["failed"] += 1
            raise (exc if exc is not None
                   else OSError("injected open failure"))
        return orig(self, spec)

    HandlePool._open = _failing
    try:
        yield counter
    finally:
        HandlePool._open = orig
