"""Test-support utilities shipped with the library.

``repro.testing.hyp`` resolves to the real `hypothesis
<https://hypothesis.readthedocs.io>`_ when it is installed (CI installs the
``dev`` extra) and otherwise to :mod:`repro.testing.minihyp`, a small
vendored property-testing fallback with the same surface — so the
property-based suites *run* everywhere instead of silently skipping in
environments without the dependency.

``repro.testing.faults`` is the fault-injection harness: deterministic
file corruptors (truncate / bit-flip / garbage append / torn footer) and
service-level injectors (TCP fault proxy, flaky handle opens) used by
the robustness suites and the crash-consistency CI gate.
"""
