"""Test-support utilities shipped with the library.

``repro.testing.hyp`` resolves to the real `hypothesis
<https://hypothesis.readthedocs.io>`_ when it is installed (CI installs the
``dev`` extra) and otherwise to :mod:`repro.testing.minihyp`, a small
vendored property-testing fallback with the same surface — so the
property-based suites *run* everywhere instead of silently skipping in
environments without the dependency.
"""
